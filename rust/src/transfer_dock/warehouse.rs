//! TD warehouse: one shard of the sample payload store, living on a node.

use anyhow::{anyhow, ensure, Result};
use std::collections::HashMap;
use std::sync::Mutex;

use super::sample::{FieldKind, PartialRollout, Sample, Segment};
use crate::runtime::Tensor;

/// Byte-conservation snapshot of one payload store: everything that ever
/// became resident is either still resident or has left through a retire
/// / overwrite — `admitted == resident + retired` at every quiescent
/// point (the chaos suite's conservation invariant).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Conservation {
    /// Σ bytes that entered residency (admissions + merged writebacks)
    pub admitted_bytes: u64,
    /// bytes currently resident
    pub resident_bytes: u64,
    /// Σ bytes that left residency (retired samples + overwritten fields)
    pub retired_bytes: u64,
}

impl Conservation {
    pub fn holds(&self) -> bool {
        self.admitted_bytes == self.resident_bytes + self.retired_bytes
    }

    pub fn merge(&mut self, other: &Conservation) {
        self.admitted_bytes += other.admitted_bytes;
        self.resident_bytes += other.resident_bytes;
        self.retired_bytes += other.retired_bytes;
    }
}

/// Outcome of a writeback merge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreOutcome {
    /// fields merged; the new presence bitmask
    Merged(u8),
    /// stale writeback dropped: the sample is gone (reclaimed claim whose
    /// sample was re-processed and retired) or a later generation already
    /// landed (generation writebacks are first-writer-wins, so a sample's
    /// response — and its `behavior_version` stamp — never changes once
    /// set, keeping every downstream recompute idempotent)
    Superseded,
}

/// A payload shard. Thread-safe; workers on any node may fetch from it,
/// and the dock records the link class of each access based on node ids.
#[derive(Debug)]
pub struct Warehouse {
    pub id: usize,
    /// node this warehouse lives on (usually id == node, one per node)
    pub node: usize,
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    samples: HashMap<u64, Sample>,
    /// cumulative bytes served + stored (congestion measure)
    traffic_bytes: u64,
    /// running resident-byte counter — kept exact in `put` /
    /// `store_fields` / `remove` so residency queries are O(1) instead of
    /// an O(n) payload scan under the mutex
    resident_bytes: u64,
    /// cumulative bytes that entered residency
    admitted_bytes: u64,
    /// cumulative bytes that left residency (retires + overwrites)
    retired_bytes: u64,
    /// stale writebacks dropped (first-writer-wins / post-retire)
    superseded: u64,
}

impl Warehouse {
    pub fn new(id: usize, node: usize) -> Self {
        Self { id, node, inner: Mutex::new(Inner::default()) }
    }

    pub fn put(&self, sample: Sample) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        let bytes = sample.payload_bytes() as u64;
        g.traffic_bytes += bytes;
        g.resident_bytes += bytes;
        g.admitted_bytes += bytes;
        if let Some(old) = g.samples.insert(sample.index, sample) {
            // defensive: replacing a resident sample retires its bytes
            let old_bytes = old.payload_bytes() as u64;
            g.resident_bytes -= old_bytes;
            g.retired_bytes += old_bytes;
        }
        Ok(())
    }

    /// Clone out a sample's payload (a fetch serves a copy; the warehouse
    /// keeps the original — consumption is an explicit `remove`).
    pub fn fetch(&self, index: u64) -> Result<Sample> {
        let mut g = self.inner.lock().unwrap();
        let s = g
            .samples
            .get(&index)
            .ok_or_else(|| anyhow!("warehouse {}: no sample {index}", self.id))?
            .clone();
        g.traffic_bytes += s.payload_bytes() as u64;
        Ok(s)
    }

    /// Merge produced fields into a stored sample. A generation writeback
    /// additionally carries the completion text, response length, and the
    /// behavior-policy weight version that produced the response.
    ///
    /// Fault tolerance makes two writeback classes *stale* rather than
    /// erroneous, both dropped as [`StoreOutcome::Superseded`]:
    /// * a writeback for a sample that is no longer resident (the claim
    ///   expired, another worker re-processed it, and the update state
    ///   already retired it);
    /// * a second generation writeback for a sample whose tokens already
    ///   landed (first writer wins, so the stamped response is immutable
    ///   and late logprob/reward recomputes stay byte-identical).
    pub fn store_fields(
        &self,
        index: u64,
        fields: Vec<(FieldKind, Tensor)>,
        completion: Option<(String, usize, u64)>,
    ) -> Result<StoreOutcome> {
        self.store_fields_with_segments(index, fields, completion, Vec::new())
    }

    /// [`Self::store_fields`] with an explicit per-version segment list
    /// for the completed response. An empty list on a completing
    /// writeback synthesizes the single full-span segment (the
    /// uninterrupted case), so every finished sample carries authoritative
    /// segment stamps. Completion also clears any persisted partial
    /// prefix — the finished response supersedes it — retiring its bytes.
    pub fn store_fields_with_segments(
        &self,
        index: u64,
        fields: Vec<(FieldKind, Tensor)>,
        completion: Option<(String, usize, u64)>,
        segments: Vec<Segment>,
    ) -> Result<StoreOutcome> {
        let mut g = self.inner.lock().unwrap();
        let field_bytes: u64 = fields.iter().map(|(_, t)| t.size_bytes() as u64).sum();
        let wire_seg_bytes = (segments.len() * Segment::WIRE_BYTES) as u64;
        // the bytes arrived at the store either way (congestion is real
        // even for a writeback that loses the race)
        g.traffic_bytes += field_bytes + wire_seg_bytes;
        let stale = match g.samples.get(&index) {
            None => true,
            Some(s) => completion.is_some() && s.has(FieldKind::Tokens),
        };
        if stale {
            g.superseded += 1;
            return Ok(StoreOutcome::Superseded);
        }
        // `added`/`overwritten` track residency deltas (what the sample
        // now holds vs what it released), which can differ from the wire
        // bytes: a completing writeback with no explicit segments stores
        // a synthesized full-span segment that never crossed the wire
        let mut added: u64 = field_bytes;
        let mut overwritten: u64 = 0;
        let s = g.samples.get_mut(&index).expect("residency checked above");
        for (k, t) in fields {
            if let Some(old) = s.get(k) {
                overwritten += old.size_bytes() as u64;
            }
            s.put(k, t);
        }
        if let Some((text, resp_len, behavior_version)) = completion {
            s.completion_text = text;
            s.resp_len = resp_len;
            s.behavior_version = behavior_version;
            let segs = if segments.is_empty() && resp_len > 0 {
                vec![Segment { start: 0, len: resp_len, version: behavior_version }]
            } else {
                segments
            };
            added += (segs.len() * Segment::WIRE_BYTES) as u64;
            overwritten += (s.segments.len() * Segment::WIRE_BYTES) as u64;
            s.segments = segs;
            // the completed response supersedes the persisted prefix
            if let Some(p) = s.partial.take() {
                overwritten += p.payload_bytes() as u64;
            }
        }
        let mask = s.present_mask();
        g.resident_bytes += added;
        g.resident_bytes -= overwritten;
        g.admitted_bytes += added;
        g.retired_bytes += overwritten;
        Ok(StoreOutcome::Merged(mask))
    }

    /// Persist the decoded prefix of an interrupted generation. Stale
    /// cases are dropped as [`StoreOutcome::Superseded`]: the sample is
    /// gone (retired), its final response already landed (partials never
    /// overwrite a finished generation), or the persisted prefix is
    /// already at least as long (longest-prefix-wins keeps a late short
    /// writer from shrinking a newer checkpoint).
    pub fn store_partial(&self, index: u64, partial: PartialRollout) -> Result<StoreOutcome> {
        ensure!(
            partial.well_formed(),
            "warehouse {}: malformed partial rollout for sample {index} \
             (segments must tile the prefix, one logprob per token)",
            self.id
        );
        let mut g = self.inner.lock().unwrap();
        let new_bytes = partial.payload_bytes() as u64;
        g.traffic_bytes += new_bytes;
        let stale = match g.samples.get(&index) {
            None => true,
            Some(s) => {
                s.has(FieldKind::Tokens)
                    || s.partial.as_ref().is_some_and(|p| p.token_len() >= partial.token_len())
            }
        };
        if stale {
            g.superseded += 1;
            return Ok(StoreOutcome::Superseded);
        }
        let s = g.samples.get_mut(&index).expect("residency checked above");
        let old_bytes =
            s.partial.replace(partial).map_or(0, |p| p.payload_bytes() as u64);
        let mask = s.present_mask();
        g.resident_bytes += new_bytes;
        g.resident_bytes -= old_bytes;
        g.admitted_bytes += new_bytes;
        g.retired_bytes += old_bytes;
        Ok(StoreOutcome::Merged(mask))
    }

    /// Metadata snapshot without cloning the payload (what a warehouse
    /// broadcasts after an update).
    pub fn fetch_meta_snapshot(&self, index: u64) -> Result<super::controller::SampleMeta> {
        let g = self.inner.lock().unwrap();
        let s = g
            .samples
            .get(&index)
            .ok_or_else(|| anyhow!("warehouse {}: no sample {index}", self.id))?;
        Ok(super::controller::SampleMeta {
            index: s.index,
            group: s.group,
            tenant: s.tenant,
            warehouse: self.id,
            present: s.present_mask(),
            prompt_len: s.prompt_len as u32,
            resp_len: s.resp_len as u32,
            behavior_version: s.behavior_version,
        })
    }

    pub fn remove(&self, index: u64) -> Option<Sample> {
        let mut g = self.inner.lock().unwrap();
        let s = g.samples.remove(&index)?;
        let bytes = s.payload_bytes() as u64;
        g.resident_bytes -= bytes;
        g.retired_bytes += bytes;
        Some(s)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn traffic_bytes(&self) -> u64 {
        self.inner.lock().unwrap().traffic_bytes
    }

    /// Bytes currently resident (memory pressure of the shard). O(1): a
    /// running counter, with a debug-mode assertion that it matches the
    /// full payload scan.
    pub fn resident_bytes(&self) -> u64 {
        let g = self.inner.lock().unwrap();
        debug_assert_eq!(
            g.resident_bytes,
            g.samples.values().map(|s| s.payload_bytes() as u64).sum::<u64>(),
            "warehouse {}: resident-byte counter diverged from the scan",
            self.id
        );
        g.resident_bytes
    }

    /// Byte-conservation snapshot (admitted / resident / retired).
    pub fn conservation(&self) -> Conservation {
        let g = self.inner.lock().unwrap();
        Conservation {
            admitted_bytes: g.admitted_bytes,
            resident_bytes: g.resident_bytes,
            retired_bytes: g.retired_bytes,
        }
    }

    /// Stale writebacks this shard dropped.
    pub fn superseded_writebacks(&self) -> u64 {
        self.inner.lock().unwrap().superseded
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(idx: u64) -> Sample {
        Sample::new_prompt(idx, 0, "1+1=".into(), 2)
    }

    #[test]
    fn put_fetch_remove() {
        let w = Warehouse::new(0, 0);
        w.put(sample(1)).unwrap();
        assert_eq!(w.len(), 1);
        let s = w.fetch(1).unwrap();
        assert_eq!(s.index, 1);
        assert_eq!(w.len(), 1, "fetch must not consume");
        assert!(w.remove(1).is_some());
        assert!(w.is_empty());
        assert!(w.fetch(1).is_err());
    }

    #[test]
    fn store_fields_updates_mask() {
        let w = Warehouse::new(0, 0);
        w.put(sample(2)).unwrap();
        let out = w
            .store_fields(
                2,
                vec![(FieldKind::Tokens, Tensor::i32(&[4], vec![1, 2, 3, 4]).unwrap())],
                Some(("2".into(), 2, 5)),
            )
            .unwrap();
        let StoreOutcome::Merged(mask) = out else { panic!("first writeback must merge") };
        assert_ne!(mask & FieldKind::Tokens.bit(), 0);
        let s = w.fetch(2).unwrap();
        assert_eq!(s.completion_text, "2");
        assert_eq!(s.resp_len, 2);
        assert_eq!(s.behavior_version, 5);
        let meta = w.fetch_meta_snapshot(2).unwrap();
        assert_eq!(meta.behavior_version, 5, "broadcast snapshot must carry the stamp");
    }

    #[test]
    fn traffic_accumulates() {
        let w = Warehouse::new(0, 0);
        w.put(sample(1)).unwrap();
        let t0 = w.traffic_bytes();
        w.fetch(1).unwrap();
        assert!(w.traffic_bytes() > t0);
    }

    #[test]
    fn resident_counter_tracks_lifecycle() {
        let w = Warehouse::new(0, 0);
        assert_eq!(w.resident_bytes(), 0);
        w.put(sample(1)).unwrap();
        let after_put = w.resident_bytes();
        assert!(after_put > 0);
        w.store_fields(1, vec![(FieldKind::OldLp, Tensor::zeros(&[7]))], None).unwrap();
        let after_field = w.resident_bytes();
        assert_eq!(after_field, after_put + 7 * 4);
        // overwriting a field with a same-size tensor keeps residency flat
        w.store_fields(1, vec![(FieldKind::OldLp, Tensor::zeros(&[7]))], None).unwrap();
        assert_eq!(w.resident_bytes(), after_field);
        w.remove(1).unwrap();
        assert_eq!(w.resident_bytes(), 0);
        let c = w.conservation();
        assert!(c.holds(), "{c:?}");
        assert_eq!(c.resident_bytes, 0);
        assert_eq!(c.admitted_bytes, c.retired_bytes);
    }

    #[test]
    fn generation_writeback_is_first_writer_wins() {
        let w = Warehouse::new(0, 0);
        w.put(sample(3)).unwrap();
        let first = w
            .store_fields(
                3,
                vec![(FieldKind::Tokens, Tensor::i32(&[4], vec![1; 4]).unwrap())],
                Some(("a".into(), 1, 7)),
            )
            .unwrap();
        assert!(matches!(first, StoreOutcome::Merged(_)));
        // a late duplicate generation (stalled worker) must be dropped
        let late = w
            .store_fields(
                3,
                vec![(FieldKind::Tokens, Tensor::i32(&[4], vec![9; 4]).unwrap())],
                Some(("b".into(), 2, 9)),
            )
            .unwrap();
        assert_eq!(late, StoreOutcome::Superseded);
        let s = w.fetch(3).unwrap();
        assert_eq!(s.completion_text, "a", "first generation must win");
        assert_eq!(s.behavior_version, 7, "stamp is immutable once set");
        assert_eq!(w.superseded_writebacks(), 1);
        assert!(w.conservation().holds());
    }

    #[test]
    fn post_retire_writeback_is_superseded_not_error() {
        let w = Warehouse::new(0, 0);
        w.put(sample(4)).unwrap();
        w.remove(4).unwrap();
        let out = w
            .store_fields(4, vec![(FieldKind::Reward, Tensor::scalar_f32(1.0))], None)
            .unwrap();
        assert_eq!(out, StoreOutcome::Superseded);
        assert_eq!(w.superseded_writebacks(), 1);
        assert!(w.conservation().holds());
    }

    fn partial(n: usize, version: u64) -> PartialRollout {
        PartialRollout {
            response_ids: (0..n as i32).collect(),
            response_logprobs: vec![-0.5; n],
            segments: vec![Segment { start: 0, len: n, version }],
        }
    }

    #[test]
    fn partial_persist_resume_and_final_writeback_conserve_bytes() {
        let w = Warehouse::new(0, 0);
        w.put(sample(5)).unwrap();
        let base = w.resident_bytes();
        // first checkpoint lands
        let out = w.store_partial(5, partial(3, 1)).unwrap();
        assert!(matches!(out, StoreOutcome::Merged(_)));
        assert_eq!(w.resident_bytes(), base + partial(3, 1).payload_bytes() as u64);
        assert!(w.conservation().holds());
        // a redispatched claim fetches the prefix back
        let s = w.fetch(5).unwrap();
        assert_eq!(s.partial.as_ref().unwrap().token_len(), 3);
        // a longer checkpoint replaces it; the old prefix's bytes retire
        w.store_partial(5, partial(5, 1)).unwrap();
        assert_eq!(w.resident_bytes(), base + partial(5, 1).payload_bytes() as u64);
        assert!(w.conservation().holds());
        // the final generation writeback clears the partial and stamps
        // the explicit segment list
        let segs = vec![
            Segment { start: 0, len: 5, version: 1 },
            Segment { start: 5, len: 2, version: 2 },
        ];
        w.store_fields_with_segments(
            5,
            vec![(FieldKind::Tokens, Tensor::i32(&[8], vec![1; 8]).unwrap())],
            Some(("done".into(), 7, 2)),
            segs.clone(),
        )
        .unwrap();
        let s = w.fetch(5).unwrap();
        assert!(s.partial.is_none(), "completion must clear the persisted prefix");
        assert_eq!(s.segments, segs);
        assert_eq!(s.behavior_version, 2);
        assert!(w.conservation().holds());
        w.remove(5).unwrap();
        assert_eq!(w.resident_bytes(), 0);
        assert!(w.conservation().holds());
    }

    #[test]
    fn stale_partials_are_superseded_once_each() {
        let w = Warehouse::new(0, 0);
        w.put(sample(6)).unwrap();
        w.store_partial(6, partial(4, 1)).unwrap();
        // a late shorter prefix (stalled writer) must not shrink it
        assert_eq!(w.store_partial(6, partial(2, 1)).unwrap(), StoreOutcome::Superseded);
        // same length is not an extension either
        assert_eq!(w.store_partial(6, partial(4, 1)).unwrap(), StoreOutcome::Superseded);
        // once the final response lands, partials never overwrite it
        w.store_fields(
            6,
            vec![(FieldKind::Tokens, Tensor::i32(&[6], vec![1; 6]).unwrap())],
            Some(("x".into(), 5, 3)),
        )
        .unwrap();
        assert_eq!(w.store_partial(6, partial(6, 3)).unwrap(), StoreOutcome::Superseded);
        // and after retire the sample is simply gone
        w.remove(6).unwrap();
        assert_eq!(w.store_partial(6, partial(7, 3)).unwrap(), StoreOutcome::Superseded);
        assert_eq!(w.superseded_writebacks(), 4, "each stale partial counts exactly once");
        assert!(w.conservation().holds());
    }

    #[test]
    fn uninterrupted_completion_synthesizes_full_span_segment() {
        let w = Warehouse::new(0, 0);
        w.put(sample(7)).unwrap();
        w.store_fields(
            7,
            vec![(FieldKind::Tokens, Tensor::i32(&[4], vec![1; 4]).unwrap())],
            Some(("y".into(), 3, 9)),
        )
        .unwrap();
        let s = w.fetch(7).unwrap();
        assert_eq!(s.segments, vec![Segment { start: 0, len: 3, version: 9 }]);
        assert!(w.conservation().holds());
        // residency counter still matches the scan (segments counted)
        w.resident_bytes();
    }

    #[test]
    fn malformed_partial_rejected_loudly() {
        let w = Warehouse::new(0, 0);
        w.put(sample(8)).unwrap();
        let mut p = partial(3, 1);
        p.response_logprobs.pop();
        assert!(w.store_partial(8, p).is_err());
    }
}
