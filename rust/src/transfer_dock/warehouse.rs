//! TD warehouse: one shard of the sample payload store, living on a node.

use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::Mutex;

use super::sample::{FieldKind, Sample};
use crate::runtime::Tensor;

/// A payload shard. Thread-safe; workers on any node may fetch from it,
/// and the dock records the link class of each access based on node ids.
#[derive(Debug)]
pub struct Warehouse {
    pub id: usize,
    /// node this warehouse lives on (usually id == node, one per node)
    pub node: usize,
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    samples: HashMap<u64, Sample>,
    /// cumulative bytes served + stored (congestion measure)
    traffic_bytes: u64,
}

impl Warehouse {
    pub fn new(id: usize, node: usize) -> Self {
        Self { id, node, inner: Mutex::new(Inner::default()) }
    }

    pub fn put(&self, sample: Sample) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        g.traffic_bytes += sample.payload_bytes() as u64;
        g.samples.insert(sample.index, sample);
        Ok(())
    }

    /// Clone out a sample's payload (a fetch serves a copy; the warehouse
    /// keeps the original — consumption is an explicit `remove`).
    pub fn fetch(&self, index: u64) -> Result<Sample> {
        let mut g = self.inner.lock().unwrap();
        let s = g
            .samples
            .get(&index)
            .ok_or_else(|| anyhow!("warehouse {}: no sample {index}", self.id))?
            .clone();
        g.traffic_bytes += s.payload_bytes() as u64;
        Ok(s)
    }

    /// Merge produced fields into a stored sample; returns the new
    /// presence bitmask. A generation writeback additionally carries the
    /// completion text, response length, and the behavior-policy weight
    /// version that produced the response.
    pub fn store_fields(
        &self,
        index: u64,
        fields: Vec<(FieldKind, Tensor)>,
        completion: Option<(String, usize, u64)>,
    ) -> Result<u8> {
        let mut g = self.inner.lock().unwrap();
        let added: u64 = fields.iter().map(|(_, t)| t.size_bytes() as u64).sum();
        let s = g
            .samples
            .get_mut(&index)
            .ok_or_else(|| anyhow!("warehouse {}: no sample {index}", self.id))?;
        for (k, t) in fields {
            s.put(k, t);
        }
        if let Some((text, resp_len, behavior_version)) = completion {
            s.completion_text = text;
            s.resp_len = resp_len;
            s.behavior_version = behavior_version;
        }
        let mask = s.present_mask();
        g.traffic_bytes += added;
        Ok(mask)
    }

    /// Metadata snapshot without cloning the payload (what a warehouse
    /// broadcasts after an update).
    pub fn fetch_meta_snapshot(&self, index: u64) -> Result<super::controller::SampleMeta> {
        let g = self.inner.lock().unwrap();
        let s = g
            .samples
            .get(&index)
            .ok_or_else(|| anyhow!("warehouse {}: no sample {index}", self.id))?;
        Ok(super::controller::SampleMeta {
            index: s.index,
            group: s.group,
            warehouse: self.id,
            present: s.present_mask(),
            prompt_len: s.prompt_len as u32,
            resp_len: s.resp_len as u32,
            behavior_version: s.behavior_version,
        })
    }

    pub fn remove(&self, index: u64) -> Option<Sample> {
        self.inner.lock().unwrap().samples.remove(&index)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn traffic_bytes(&self) -> u64 {
        self.inner.lock().unwrap().traffic_bytes
    }

    /// Bytes currently resident (memory pressure of the shard).
    pub fn resident_bytes(&self) -> u64 {
        let g = self.inner.lock().unwrap();
        g.samples.values().map(|s| s.payload_bytes() as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(idx: u64) -> Sample {
        Sample::new_prompt(idx, 0, "1+1=".into(), 2)
    }

    #[test]
    fn put_fetch_remove() {
        let w = Warehouse::new(0, 0);
        w.put(sample(1)).unwrap();
        assert_eq!(w.len(), 1);
        let s = w.fetch(1).unwrap();
        assert_eq!(s.index, 1);
        assert_eq!(w.len(), 1, "fetch must not consume");
        assert!(w.remove(1).is_some());
        assert!(w.is_empty());
        assert!(w.fetch(1).is_err());
    }

    #[test]
    fn store_fields_updates_mask() {
        let w = Warehouse::new(0, 0);
        w.put(sample(2)).unwrap();
        let mask = w
            .store_fields(
                2,
                vec![(FieldKind::Tokens, Tensor::i32(&[4], vec![1, 2, 3, 4]).unwrap())],
                Some(("2".into(), 2, 5)),
            )
            .unwrap();
        assert_ne!(mask & FieldKind::Tokens.bit(), 0);
        let s = w.fetch(2).unwrap();
        assert_eq!(s.completion_text, "2");
        assert_eq!(s.resp_len, 2);
        assert_eq!(s.behavior_version, 5);
        let meta = w.fetch_meta_snapshot(2).unwrap();
        assert_eq!(meta.behavior_version, 5, "broadcast snapshot must carry the stamp");
    }

    #[test]
    fn traffic_accumulates() {
        let w = Warehouse::new(0, 0);
        w.put(sample(1)).unwrap();
        let t0 = w.traffic_bytes();
        w.fetch(1).unwrap();
        assert!(w.traffic_bytes() > t0);
    }
}
