//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Warmup + timed iterations with mean / p50 / p95 / stddev reporting, and
//! a table printer used by the paper-reproduction benches to emit the same
//! rows/series the paper reports. Results can also be dumped as JSON into
//! `results/` for EXPERIMENTS.md.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub std_s: f64,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>10} {:>10} {:>10} ±{:>9} ({} iters)",
            self.name,
            crate::util::fmt_secs(self.mean_s),
            crate::util::fmt_secs(self.p50_s),
            crate::util::fmt_secs(self.p95_s),
            crate::util::fmt_secs(self.std_s),
            self.iters
        )
    }
}

pub fn header() -> String {
    format!(
        "{:<44} {:>10} {:>10} {:>10} {:>10}",
        "benchmark", "mean", "p50", "p95", "std"
    )
}

/// Run `f` with warmup, then time `iters` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    summarize(name, &mut samples)
}

/// Time a batch-style closure that reports how many inner ops it ran;
/// returns per-op stats.
pub fn bench_throughput<F: FnMut() -> usize>(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: F,
) -> (BenchResult, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    let mut total_ops = 0usize;
    let mut total_time = 0f64;
    for _ in 0..iters {
        let t0 = Instant::now();
        let ops = f();
        let dt = t0.elapsed().as_secs_f64();
        samples.push(dt);
        total_ops += ops;
        total_time += dt;
    }
    let r = summarize(name, &mut samples);
    let ops_per_sec = total_ops as f64 / total_time.max(1e-12);
    (r, ops_per_sec)
}

fn summarize(name: &str, samples: &mut [f64]) -> BenchResult {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len().max(1);
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_s: mean,
        p50_s: samples[(n - 1) / 2],
        p95_s: samples[((n - 1) as f64 * 0.95) as usize],
        std_s: var.sqrt(),
    }
}

/// Machine-readable bench summary for the CI perf gate: every bench's
/// `--json` mode emits one single-line JSON object to stdout and to
/// `target/bench/<name>.json`, which `ci/bench_gate.py` merges into
/// `BENCH_PR.json` and diffs against the committed `bench-baseline.json`
/// (>10% regression on any gated metric fails the job).
///
/// Metrics come in two buckets:
/// * **gated** (`higher` / `lower` by better-direction) — deterministic
///   values only: analytic volumes, cost-model TPS, ledger-derived
///   dispatch seconds, tracked-pool byte counts. These are what the CI
///   gate compares run-over-run.
/// * **info** — wall-clock measurements and anything artifact-dependent;
///   recorded for the artifact trail, never gated (CI runners are too
///   noisy for a 10% wall-clock gate to mean anything).
#[derive(Debug, Default)]
pub struct BenchJson {
    name: String,
    gated_higher: Vec<(String, f64)>,
    gated_lower: Vec<(String, f64)>,
    info: Vec<(String, f64)>,
}

/// JSON-safe float: the format has no NaN/Inf, and a non-finite metric
/// is a bench bug — surface it as an impossible sentinel rather than
/// emitting invalid JSON.
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "-1".into()
    }
}

fn json_map(pairs: &[(String, f64)]) -> String {
    let body = pairs
        .iter()
        .map(|(k, v)| {
            debug_assert!(
                k.chars().all(|c| c.is_ascii_alphanumeric() || c == '_'),
                "metric keys must be snake_case identifiers: {k:?}"
            );
            format!("\"{k}\":{}", json_num(*v))
        })
        .collect::<Vec<_>>()
        .join(",");
    format!("{{{body}}}")
}

impl BenchJson {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), ..Default::default() }
    }

    /// Gated metric where bigger is better (throughput, speedup, saved bytes).
    pub fn higher(&mut self, key: &str, v: f64) -> &mut Self {
        self.gated_higher.push((key.to_string(), v));
        self
    }

    /// Gated metric where smaller is better (seconds, bytes held).
    pub fn lower(&mut self, key: &str, v: f64) -> &mut Self {
        self.gated_lower.push((key.to_string(), v));
        self
    }

    /// Ungated context metric (wall-clock and artifact-dependent values).
    pub fn info(&mut self, key: &str, v: f64) -> &mut Self {
        self.info.push((key.to_string(), v));
        self
    }

    /// The single-line JSON document.
    pub fn render(&self) -> String {
        format!(
            "{{\"bench\":\"{}\",\"gated\":{{\"higher\":{},\"lower\":{}}},\"info\":{}}}",
            self.name,
            json_map(&self.gated_higher),
            json_map(&self.gated_lower),
            json_map(&self.info)
        )
    }

    /// Print the summary line and write `target/bench/<name>.json`.
    pub fn emit(&self) -> anyhow::Result<std::path::PathBuf> {
        let line = self.render();
        println!("{line}");
        let dir = std::path::Path::new("target/bench");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.json", self.name));
        std::fs::write(&path, format!("{line}\n"))?;
        Ok(path)
    }
}

/// Fixed-width table printer for paper-shaped output.
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            columns: columns.iter().map(|c| c.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.columns, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let r = bench("noop-ish", 2, 20, || {
            std::hint::black_box((0..100).sum::<usize>());
        });
        assert_eq!(r.iters, 20);
        assert!(r.mean_s >= 0.0 && r.p50_s <= r.p95_s);
    }

    #[test]
    fn throughput_counts_ops() {
        let (_, ops) = bench_throughput("batch", 0, 5, || 100);
        assert!(ops > 0.0);
    }

    #[test]
    fn bench_json_round_trips_through_the_parser() {
        let mut j = BenchJson::new("stage_scaling");
        j.higher("modeled_tps_r4", 123.5)
            .lower("dispatch_secs", 0.25)
            .info("wall_secs", f64::NAN);
        let line = j.render();
        assert!(!line.contains('\n'), "summary must be single-line");
        let parsed = crate::util::json::Json::parse(&line).expect("valid JSON");
        assert_eq!(parsed.get("bench").unwrap().str().unwrap(), "stage_scaling");
        let gated = parsed.get("gated").unwrap();
        assert_eq!(
            gated.get("higher").unwrap().get("modeled_tps_r4").unwrap().num().unwrap(),
            123.5
        );
        assert_eq!(gated.get("lower").unwrap().get("dispatch_secs").unwrap().num().unwrap(), 0.25);
        // non-finite values become the -1 sentinel, never invalid JSON
        assert_eq!(parsed.get("info").unwrap().get("wall_secs").unwrap().num().unwrap(), -1.0);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("bb"));
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}
