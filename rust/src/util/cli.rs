//! Minimal CLI argument parsing (clap is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments, with typed getters and a generated usage string.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without the program name).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    out.bools.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name) || self.flags.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects a number, got {v:?}")),
        }
    }

    pub fn f32_or(&self, name: &str, default: f32) -> Result<f32> {
        Ok(self.f64_or(name, default as f64)? as f32)
    }

    /// Error if any unexpected flag was passed (catches typos).
    pub fn expect_only(&self, allowed: &[&str]) -> Result<()> {
        for k in self.flags.keys().chain(self.bools.iter()) {
            if !allowed.contains(&k.as_str()) {
                bail!("unknown flag --{k}; allowed: {allowed:?}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn flags_and_positional() {
        // note: a bare `--flag` followed by a non-flag token consumes it as
        // a value, so boolean flags go last or use `--flag=true`
        let a = parse("train extra --preset small --iters=10 --verbose");
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.get("preset"), Some("small"));
        assert_eq!(a.usize_or("iters", 0).unwrap(), 10);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.usize_or("n", 7).unwrap(), 7);
        assert_eq!(a.f64_or("lr", 0.5).unwrap(), 0.5);
        assert_eq!(a.str_or("mode", "x"), "x");
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("--n abc");
        assert!(a.usize_or("n", 0).is_err());
    }

    #[test]
    fn expect_only_catches_typos() {
        let a = parse("--preseet tiny");
        assert!(a.expect_only(&["preset"]).is_err());
        let b = parse("--preset tiny");
        assert!(b.expect_only(&["preset"]).is_ok());
    }
}
