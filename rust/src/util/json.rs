//! Minimal JSON parser/serializer (serde is unavailable in the offline
//! build environment — see DESIGN.md substitutions). Supports the full
//! JSON grammar needed by the artifact manifest and the results emitters:
//! objects, arrays, strings (with escapes), numbers, booleans, null.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ------------------------------------------------------------ access
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key).filter(|v| !matches!(v, Json::Null)),
            _ => None,
        }
    }

    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn num(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number"),
        }
    }

    pub fn u64(&self) -> Result<u64> {
        Ok(self.num()? as u64)
    }

    pub fn usize(&self) -> Result<usize> {
        Ok(self.num()? as usize)
    }

    pub fn bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool"),
        }
    }

    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array"),
        }
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.arr()?.iter().map(|v| v.usize()).collect()
    }

    pub fn str_vec(&self) -> Result<Vec<String>> {
        self.arr()?.iter().map(|v| Ok(v.str()?.to_string())).collect()
    }

    // ------------------------------------------------------------ build
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64s(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    // ------------------------------------------------------------ parse
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    // ------------------------------------------------------------ emit
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected {:?} at byte {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        c => bail!("bad escape \\{:?}", c as char),
                    }
                }
                b if b < 0x80 => s.push(b as char),
                _ => {
                    // multi-byte UTF-8: find the full char from the source
                    let start = self.pos - 1;
                    let text = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| anyhow!("invalid utf8 in string"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos = start + c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|_| anyhow!("bad number {text:?}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let j = Json::parse(
            r#"{"a": 1, "b": [1, 2.5, -3], "c": {"d": "x\ny", "e": null}, "f": true}"#,
        )
        .unwrap();
        assert_eq!(j.get("a").unwrap().usize().unwrap(), 1);
        assert_eq!(j.get("b").unwrap().arr().unwrap().len(), 3);
        assert_eq!(j.get("c").unwrap().get("d").unwrap().str().unwrap(), "x\ny");
        assert!(j.get("c").unwrap().opt("e").is_none());
        assert!(j.get("f").unwrap().bool().unwrap());
    }

    #[test]
    fn round_trip() {
        let src = r#"{"k":[{"x":1},"s",false,null,2.5]}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn escapes_round_trip() {
        let j = Json::Str("a\"b\\c\nd\u{1}".to_string());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn unicode() {
        let j = Json::parse(r#""héllo é""#).unwrap();
        assert_eq!(j.str().unwrap(), "héllo é");
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn big_ints_exact() {
        let j = Json::parse("1234567890123").unwrap();
        assert_eq!(j.u64().unwrap(), 1234567890123);
        assert_eq!(j.to_string(), "1234567890123");
    }
}
