//! In-crate substrates for facilities the offline build cannot pull from
//! crates.io: JSON, deterministic RNG, CLI parsing, bench harness, and a
//! tiny property-testing helper (see DESIGN.md substitutions).

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;

/// Format a byte count human-readably.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format seconds human-readably (µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2}s")
    } else {
        format!("{:.1}min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024 * 1024), "3.00 GiB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(0.5e-4), "50.0µs");
        assert_eq!(fmt_secs(0.25), "250.00ms");
        assert_eq!(fmt_secs(2.5), "2.50s");
        assert_eq!(fmt_secs(600.0), "10.0min");
    }
}
