//! Deterministic RNG (splitmix64 + xoshiro256++) — the offline environment
//! has no `rand` crate; this is the project-wide randomness substrate.
//! Determinism matters: every experiment in EXPERIMENTS.md is reproducible
//! from its seed.

/// xoshiro256++ seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 to fill the state (never all-zero)
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Fast-forward the stream by `n` draws. Resuming an interrupted
    /// per-sequence token stream from a persisted prefix requires the RNG
    /// to sit exactly where an uninterrupted run would have left it —
    /// skip `prefix_tokens × draws_per_token` and the continuation is
    /// bit-identical.
    pub fn skip(&mut self, n: usize) {
        for _ in 0..n {
            self.next_u64();
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len().max(1));
        }
        let mut t = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample from softmax(logits / temperature).
    pub fn sample_logits(&mut self, logits: &[f32], temperature: f32) -> usize {
        if temperature <= 1e-6 {
            // argmax
            return logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0);
        }
        let inv_t = 1.0 / temperature as f64;
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let weights: Vec<f64> =
            logits.iter().map(|&l| ((l as f64 - max) * inv_t).exp()).collect();
        self.categorical(&weights)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn skip_matches_discarded_draws() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..17 {
            a.next_u64();
        }
        b.skip(17);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn argmax_sampling_at_zero_temperature() {
        let mut r = Rng::new(5);
        assert_eq!(r.sample_logits(&[0.0, 5.0, 1.0], 0.0), 1);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 2];
        for _ in 0..5000 {
            counts[r.categorical(&[1.0, 3.0])] += 1;
        }
        let frac = counts[1] as f64 / 5000.0;
        assert!((frac - 0.75).abs() < 0.05, "frac {frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
