//! Versioned weight flow: the train→infer weight channel of the pipelined
//! executor, with behavior-policy identity as a first-class concept.
//!
//! The paper's resharding flow exists to keep the inference engine's
//! weights coherent with training while generation and update overlap.
//! PR 1 approximated it with a single-head bus, which meant the
//! old-logprob stage could only ever score against the *newest* weights —
//! a silent off-policy bias once `--max-inflight > 1`. This module makes
//! the weight channel versioned instead:
//!
//! * [`WeightBus::publish`] returns a monotonically increasing
//!   [`WeightVersion`]; the bus retains a bounded ring of snapshots.
//! * Every sample is stamped with the version active when it was
//!   generated (`Sample::behavior_version`, threaded through the
//!   transfer dock), and the old-logprob stage scores each claimed batch
//!   under its *recorded* version via [`WeightBus::get`] — the importance
//!   ratio's denominator is the true behavior policy, exactly as
//!   HybridFlow/DistFlow tag rollout batches with the producing policy
//!   version to keep ratios well-defined under asynchrony.
//! * Eviction is tied to the executor's staleness window: while a sample
//!   is in flight its iteration cannot complete (though earlier ones can,
//!   admitting successors), admission is gated at
//!   `completed + max_inflight_iters`, and every publish retires at least
//!   one whole GRPO group — so at most
//!   `(2 × max_inflight_iters − 1) × G` publishes can land between a
//!   sample's generation and its scoring (see the executor's
//!   `bus_capacity` for the full derivation). A ring sized to that bound
//!   never evicts a version still referenced by an in-flight sample; a
//!   reader that nevertheless asks for an evicted (or not-yet-published)
//!   version gets a typed [`WeightBusError`], never a panic.

use std::collections::VecDeque;
use std::fmt;
use std::sync::{Arc, Mutex};

use crate::runtime::{Policy, Tensor};

/// Identity of one published weight snapshot. Version 1 is the initial
/// (pre-RL) parameters; every [`WeightBus::publish`] increments it.
/// `0` never names a snapshot — sample stamps use it for "unstamped".
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct WeightVersion(pub u64);

impl WeightVersion {
    pub fn as_u64(self) -> u64 {
        self.0
    }
}

impl fmt::Display for WeightVersion {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "v{}", self.0)
    }
}

/// Typed failure of a versioned read — the regression the stress suite
/// pins is that an evicted version is an *error value*, not a panic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightBusError {
    /// The version fell out of the retention ring. Under the executor's
    /// sizing invariant this indicates a staleness-window bug upstream.
    Evicted { requested: u64, oldest: u64, newest: u64 },
    /// The version has not been published yet.
    NotYetPublished { requested: u64, newest: u64 },
}

impl fmt::Display for WeightBusError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WeightBusError::Evicted { requested, oldest, newest } => write!(
                f,
                "weight version v{requested} evicted from the bus (ring holds v{oldest}..=v{newest})"
            ),
            WeightBusError::NotYetPublished { requested, newest } => {
                write!(f, "weight version v{requested} not yet published (newest is v{newest})")
            }
        }
    }
}

impl std::error::Error for WeightBusError {}

/// Single-producer, multi-reader ring of versioned weight snapshots.
///
/// `publish` copies the weights outside the lock, so replica refreshes on
/// the inference hot path only ever block on a pointer swap. Snapshots
/// are `Arc`ed: eviction drops the ring's reference, but a reader already
/// holding the snapshot keeps it alive.
pub struct WeightBus {
    capacity: usize,
    /// dense ascending (version, snapshot) pairs; never empty
    inner: Mutex<VecDeque<(u64, Arc<Vec<Tensor>>)>>,
}

impl WeightBus {
    /// Seed the bus with the initial parameters as version 1, retaining
    /// up to `capacity` snapshots (clamped to at least 1).
    pub fn new(initial: Vec<Tensor>, capacity: usize) -> Self {
        let mut ring = VecDeque::new();
        ring.push_back((1u64, Arc::new(initial)));
        Self { capacity: capacity.max(1), inner: Mutex::new(ring) }
    }

    /// Publish a new snapshot; returns its version. Evicts the oldest
    /// snapshots beyond `capacity`.
    pub fn publish(&self, params: &[Tensor]) -> WeightVersion {
        let next = Arc::new(params.to_vec());
        let mut g = self.inner.lock().unwrap();
        let v = g.back().map(|(v, _)| v + 1).expect("bus ring is never empty");
        g.push_back((v, next));
        while g.len() > self.capacity {
            g.pop_front();
        }
        WeightVersion(v)
    }

    /// Newest snapshot and its version.
    pub fn head(&self) -> (WeightVersion, Arc<Vec<Tensor>>) {
        let g = self.inner.lock().unwrap();
        let (v, p) = g.back().expect("bus ring is never empty");
        (WeightVersion(*v), p.clone())
    }

    /// Newest version number without cloning the snapshot.
    pub fn head_version(&self) -> WeightVersion {
        WeightVersion(self.inner.lock().unwrap().back().unwrap().0)
    }

    /// Oldest version still retained.
    pub fn oldest(&self) -> WeightVersion {
        WeightVersion(self.inner.lock().unwrap().front().unwrap().0)
    }

    /// Fetch a specific snapshot still inside the retention ring.
    pub fn get(&self, version: WeightVersion) -> Result<Arc<Vec<Tensor>>, WeightBusError> {
        let g = self.inner.lock().unwrap();
        let oldest = g.front().unwrap().0;
        let newest = g.back().unwrap().0;
        if version.0 > newest {
            return Err(WeightBusError::NotYetPublished { requested: version.0, newest });
        }
        if version.0 < oldest {
            return Err(WeightBusError::Evicted { requested: version.0, oldest, newest });
        }
        // versions are dense and ascending, so the ring indexes directly
        Ok(g[(version.0 - oldest) as usize].1.clone())
    }

    /// Newest snapshot strictly newer than `seen`, if any (the replica
    /// refresh primitive).
    pub fn newer_than(&self, seen: WeightVersion) -> Option<(WeightVersion, Arc<Vec<Tensor>>)> {
        let g = self.inner.lock().unwrap();
        let (v, p) = g.back().expect("bus ring is never empty");
        if *v > seen.0 {
            Some((WeightVersion(*v), p.clone()))
        } else {
            None
        }
    }

    /// Snapshots currently retained.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        false // the ring always holds at least the newest snapshot
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

impl fmt::Debug for WeightBus {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let g = self.inner.lock().unwrap();
        f.debug_struct("WeightBus")
            .field("capacity", &self.capacity)
            .field("oldest", &g.front().unwrap().0)
            .field("newest", &g.back().unwrap().0)
            .finish()
    }
}

/// A stage thread's head-tracking inference replica (used by generation,
/// which always wants the freshest weights and stamps what it got).
pub struct WeightReplica {
    pub version: WeightVersion,
    pub policy: Policy,
}

impl WeightReplica {
    pub fn new(bus: &WeightBus) -> Self {
        let (version, params) = bus.head();
        Self { version, policy: Policy::from_params((*params).clone()) }
    }

    /// Pick up the newest snapshot if the bus moved; returns whether the
    /// replica changed.
    pub fn refresh(&mut self, bus: &WeightBus) -> bool {
        match bus.newer_than(self.version) {
            Some((version, params)) => {
                self.version = version;
                self.policy = Policy::from_params((*params).clone());
                true
            }
            None => false,
        }
    }
}

/// Small MRU cache of *version-pinned* replicas for the old-logprob
/// stage: claimed batches arrive grouped by stamped version, and
/// adjacent batches usually share a version, so a handful of entries
/// avoids rebuilding a `Policy` (one params clone) per batch.
pub struct ReplicaCache {
    cap: usize,
    /// most-recently-used last
    entries: Vec<(u64, Policy)>,
}

impl ReplicaCache {
    pub fn new(cap: usize) -> Self {
        Self { cap: cap.max(1), entries: Vec::new() }
    }

    /// Replica for `version`, built from the bus on a miss. Propagates
    /// the bus's typed error if the version is outside the ring.
    pub fn get_or_build(
        &mut self,
        bus: &WeightBus,
        version: WeightVersion,
    ) -> Result<&Policy, WeightBusError> {
        if let Some(i) = self.entries.iter().position(|(v, _)| *v == version.0) {
            let hit = self.entries.remove(i);
            self.entries.push(hit);
        } else {
            let params = bus.get(version)?;
            if self.entries.len() >= self.cap {
                self.entries.remove(0);
            }
            self.entries.push((version.0, Policy::from_params((*params).clone())));
        }
        Ok(&self.entries.last().unwrap().1)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(tag: f32) -> Vec<Tensor> {
        vec![Tensor::f32(&[2], vec![tag, tag + 0.5]).unwrap()]
    }

    fn tag_of(p: &[Tensor]) -> f32 {
        p[0].as_f32().unwrap()[0]
    }

    #[test]
    fn publish_is_monotone_from_one() {
        let bus = WeightBus::new(params(0.0), 4);
        assert_eq!(bus.head_version(), WeightVersion(1));
        for i in 1..=5u64 {
            let v = bus.publish(&params(i as f32));
            assert_eq!(v, WeightVersion(i + 1));
        }
        assert_eq!(bus.head_version(), WeightVersion(6));
    }

    #[test]
    fn get_returns_the_exact_snapshot() {
        let bus = WeightBus::new(params(1.0), 8);
        bus.publish(&params(2.0));
        bus.publish(&params(3.0));
        for v in 1..=3u64 {
            let snap = bus.get(WeightVersion(v)).unwrap();
            assert_eq!(tag_of(&snap), v as f32);
        }
    }

    #[test]
    fn eviction_honours_capacity_and_is_typed() {
        let bus = WeightBus::new(params(1.0), 2);
        bus.publish(&params(2.0));
        bus.publish(&params(3.0)); // evicts v1
        assert_eq!(bus.len(), 2);
        assert_eq!(bus.oldest(), WeightVersion(2));
        match bus.get(WeightVersion(1)) {
            Err(WeightBusError::Evicted { requested: 1, oldest: 2, newest: 3 }) => {}
            other => panic!("expected typed eviction error, got {other:?}"),
        }
        match bus.get(WeightVersion(9)) {
            Err(WeightBusError::NotYetPublished { requested: 9, newest: 3 }) => {}
            other => panic!("expected not-yet-published error, got {other:?}"),
        }
    }

    #[test]
    fn evicted_snapshot_survives_through_existing_arcs() {
        let bus = WeightBus::new(params(1.0), 1);
        let held = bus.get(WeightVersion(1)).unwrap();
        bus.publish(&params(2.0)); // v1 leaves the ring
        assert!(matches!(bus.get(WeightVersion(1)), Err(WeightBusError::Evicted { .. })));
        assert_eq!(tag_of(&held), 1.0, "reader-held Arc must stay valid");
    }

    #[test]
    fn newer_than_only_reports_progress() {
        let bus = WeightBus::new(params(1.0), 4);
        assert!(bus.newer_than(WeightVersion(1)).is_none());
        bus.publish(&params(2.0));
        let (v, p) = bus.newer_than(WeightVersion(1)).unwrap();
        assert_eq!(v, WeightVersion(2));
        assert_eq!(tag_of(&p), 2.0);
        assert!(bus.newer_than(WeightVersion(2)).is_none());
    }

    #[test]
    fn replica_cache_pins_versions_and_evicts_lru() {
        let bus = WeightBus::new(params(1.0), 8);
        bus.publish(&params(2.0));
        bus.publish(&params(3.0));
        let mut cache = ReplicaCache::new(2);
        let p1 = cache.get_or_build(&bus, WeightVersion(1)).unwrap();
        assert_eq!(tag_of(&p1.params), 1.0);
        cache.get_or_build(&bus, WeightVersion(2)).unwrap();
        assert_eq!(cache.len(), 2);
        // touch v1 so v2 is the LRU, then bring in v3
        cache.get_or_build(&bus, WeightVersion(1)).unwrap();
        cache.get_or_build(&bus, WeightVersion(3)).unwrap();
        assert_eq!(cache.len(), 2);
        // v1 and v3 remain; all resolvable without error
        assert_eq!(tag_of(&cache.get_or_build(&bus, WeightVersion(1)).unwrap().params), 1.0);
        assert_eq!(tag_of(&cache.get_or_build(&bus, WeightVersion(3)).unwrap().params), 3.0);
        // an evicted bus version surfaces the typed error through the cache
        let tight = WeightBus::new(params(1.0), 1);
        tight.publish(&params(2.0));
        let mut c2 = ReplicaCache::new(2);
        assert!(matches!(
            c2.get_or_build(&tight, WeightVersion(1)),
            Err(WeightBusError::Evicted { .. })
        ));
    }
}
