//! Versioned weight flow: the train→infer weight channel of the pipelined
//! executor, with behavior-policy identity as a first-class concept.
//!
//! The paper's resharding flow exists to keep the inference engine's
//! weights coherent with training while generation and update overlap.
//! PR 1 approximated it with a single-head bus, which meant the
//! old-logprob stage could only ever score against the *newest* weights —
//! a silent off-policy bias once `--max-inflight > 1`. PR 2 made the
//! channel versioned; this revision makes retention **shard-level and
//! content-deduplicated**, because a ring of full parameter snapshots is
//! exactly the redundant-memory pattern the paper's allgather–swap
//! strategy exists to kill (Eq. 3, Figs. 5/10):
//!
//! * A published version is a vector of [`WeightShard`]s, one per tensor
//!   index, each keyed by its **content epoch** — the version whose
//!   publish last changed that tensor. [`WeightBus::publish`] compares
//!   each tensor against the head and stores a new shard only where the
//!   content actually changed; unchanged tensors share the previous
//!   shard's `Arc`. Worst-case bus memory drops from
//!   `capacity × full-model` to `1 full model + Σ changed shards`.
//! * [`WeightBus::get`] reconstructs any retained version as a
//!   [`WeightView`] — a view over the shared shards, bit-identical to a
//!   from-scratch snapshot (pinned by `tests/weight_bus_stress.rs`).
//! * Retention is charged to an optional tracked
//!   [`MemoryPool`](crate::memory::MemoryPool): every unique retained
//!   shard allocates a pool buffer and frees it when the last retaining
//!   version evicts, so Fig-10-style accounting covers the weight channel
//!   (`pool.live_bytes() == bus.retained_bytes()` is an invariant the
//!   stress suite asserts).
//! * Every sample is stamped with the version active when it was
//!   generated (`Sample::behavior_version`, threaded through the
//!   transfer dock), and the old-logprob stage scores each claimed batch
//!   under its *recorded* version via [`WeightBus::get`] — the importance
//!   ratio's denominator is the true behavior policy, exactly as
//!   HybridFlow/DistFlow tag rollout batches with the producing policy
//!   version to keep ratios well-defined under asynchrony.
//! * Eviction is tied to the executor's staleness window (see
//!   [`WeightBus::required_capacity`]); a ring sized to that bound never
//!   evicts a version still referenced by an in-flight sample, and
//!   [`WeightBus::new_checked`] rejects a capacity below the bound
//!   **at build time** with a typed error instead of failing mid-run
//!   deep inside the old-logprob stage. A reader that nevertheless asks
//!   for an evicted (or not-yet-published) version gets a typed
//!   [`WeightBusError`], never a panic.
//!
//! The resharding flow publishes directly into the bus:
//! `Resharder::reshard_allgather_swap_into` turns its generation-layout
//! slices into one bus version without materializing a full model copy —
//! see `resharding/engine.rs`.

use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::sync::{Arc, Mutex};

use crate::memory::{BufferId, MemoryPool};
use crate::metrics::BusRetention;
use crate::runtime::{Policy, Tensor};

/// Identity of one published weight snapshot. Version 1 is the initial
/// (pre-RL) parameters; every [`WeightBus::publish`] increments it.
/// `0` never names a snapshot — sample stamps use it for "unstamped".
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct WeightVersion(pub u64);

impl WeightVersion {
    pub fn as_u64(self) -> u64 {
        self.0
    }
}

impl fmt::Display for WeightVersion {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "v{}", self.0)
    }
}

/// Typed failure of a bus operation — the regression the stress suite
/// pins is that an evicted version is an *error value*, not a panic, and
/// that an undersized ring is rejected at build time, not mid-run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightBusError {
    /// The version fell out of the retention ring. Under the executor's
    /// sizing invariant this indicates a staleness-window bug upstream.
    Evicted { requested: u64, oldest: u64, newest: u64 },
    /// The version has not been published yet.
    NotYetPublished { requested: u64, newest: u64 },
    /// Ring capacity below what the staleness window requires — caught
    /// at config/build time by [`WeightBus::new_checked`].
    CapacityBelowWindow { capacity: usize, required: usize, window: usize },
    /// A publish changed the tensor universe (the bus is keyed by tensor
    /// index; every version must cover the same indices).
    WrongTensorCount { got: usize, expect: usize },
    /// `publish_delta` named a tensor index outside the universe.
    TensorIndexOutOfRange { index: usize, n_tensors: usize },
    /// The attached accounting pool could not admit a new shard.
    PoolExhausted { requested_bytes: u64, free_bytes: u64 },
}

impl fmt::Display for WeightBusError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WeightBusError::Evicted { requested, oldest, newest } => write!(
                f,
                "weight version v{requested} evicted from the bus (ring holds v{oldest}..=v{newest})"
            ),
            WeightBusError::NotYetPublished { requested, newest } => {
                write!(f, "weight version v{requested} not yet published (newest is v{newest})")
            }
            WeightBusError::CapacityBelowWindow { capacity, required, window } => write!(
                f,
                "weight bus capacity {capacity} below the {required} snapshots the \
                 staleness window {window} requires — a still-stamped version would be \
                 evicted mid-run"
            ),
            WeightBusError::WrongTensorCount { got, expect } => {
                write!(f, "publish with {got} tensors on a bus of {expect}")
            }
            WeightBusError::TensorIndexOutOfRange { index, n_tensors } => {
                write!(f, "publish_delta tensor index {index} outside universe of {n_tensors}")
            }
            WeightBusError::PoolExhausted { requested_bytes, free_bytes } => write!(
                f,
                "bus accounting pool exhausted ({} requested, {} free)",
                crate::util::fmt_bytes(*requested_bytes),
                crate::util::fmt_bytes(*free_bytes)
            ),
        }
    }
}

impl std::error::Error for WeightBusError {}

/// One tensor's content at one point in publish history. `epoch` is the
/// version whose publish minted this content — two versions whose tensor
/// `i` shards share an epoch share the same `Arc` (and the same bytes).
#[derive(Debug)]
pub struct WeightShard {
    pub tensor_idx: usize,
    /// content epoch: the version that last changed this tensor
    pub epoch: u64,
    pub data: Tensor,
}

impl WeightShard {
    pub fn bytes(&self) -> u64 {
        self.data.size_bytes() as u64
    }

    fn key(&self) -> ShardKey {
        (self.tensor_idx, self.epoch)
    }
}

type ShardKey = (usize, u64);

/// A retained version reconstructed as a view over shared shards —
/// bit-identical to the full snapshot that was published, at the cost of
/// only the `Arc`s. Holding a view keeps its shards alive across bus
/// eviction (the accounting pool charge is released on eviction
/// regardless; a view is a reader-side borrow, not bus retention).
#[derive(Debug, Clone)]
pub struct WeightView {
    version: WeightVersion,
    shards: Vec<Arc<WeightShard>>,
}

impl WeightView {
    pub fn version(&self) -> WeightVersion {
        self.version
    }

    /// Tensors in the view (the bus's tensor universe size).
    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    pub fn tensor(&self, i: usize) -> &Tensor {
        &self.shards[i].data
    }

    pub fn shard(&self, i: usize) -> &Arc<WeightShard> {
        &self.shards[i]
    }

    pub fn tensors(&self) -> impl Iterator<Item = &Tensor> {
        self.shards.iter().map(|s| &s.data)
    }

    /// Materialize the full snapshot (one copy — what building an
    /// inference replica costs anyway).
    pub fn to_params(&self) -> Vec<Tensor> {
        self.shards.iter().map(|s| s.data.clone()).collect()
    }

    /// Bytes of the full snapshot this view represents.
    pub fn total_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.bytes()).sum()
    }
}

/// Bookkeeping for one unique retained shard.
struct Retained {
    /// how many retained versions reference this shard
    refs: usize,
    bytes: u64,
    /// accounting-pool buffer (None when no pool is attached)
    buffer: Option<BufferId>,
}

#[derive(Default)]
struct Ring {
    /// dense ascending (version, shards) entries; never empty
    ring: VecDeque<(u64, Vec<Arc<WeightShard>>)>,
    /// unique shards currently retained by the ring, by (tensor, epoch)
    retained: HashMap<ShardKey, Retained>,
    /// Σ bytes of unique retained shards (== pool live bytes)
    unique_bytes: u64,
    peak_unique_bytes: u64,
}

impl Ring {
    /// What full-copy retention of the same versions would hold.
    fn naive_equivalent_bytes(&self) -> u64 {
        self.ring
            .iter()
            .map(|(_, shards)| shards.iter().map(|s| s.bytes()).sum::<u64>())
            .sum()
    }
}

/// Single-producer, multi-reader ring of versioned weight snapshots with
/// shard-level, content-deduplicated retention.
///
/// `publish` runs the content compare and the clones of changed tensors
/// outside the lock (against a head snapshot), so readers on the
/// generation hot path only ever block on the ring insert. Shards are
/// `Arc`ed: eviction drops the ring's references, but a reader already
/// holding a [`WeightView`] keeps its shards alive.
pub struct WeightBus {
    capacity: usize,
    pool: Option<Arc<MemoryPool>>,
    inner: Mutex<Ring>,
}

impl WeightBus {
    /// Ring capacity the executor's staleness window requires: while a
    /// sample awaits scoring its iteration cannot complete, but earlier
    /// ones can — admitting successors up to `window − 1` ahead — so at
    /// most `(2·window − 1) × prompts_per_iter` publishes (each retires
    /// at least one whole GRPO group) can land between a sample's stamp
    /// and its scoring; +2 covers the stamp itself and slop (full
    /// derivation in `trainers/executor.rs`).
    pub fn required_capacity(window: usize, prompts_per_iter: usize) -> usize {
        (2 * window.max(1) - 1) * prompts_per_iter.max(1) + 2
    }

    /// Seed the bus with the initial parameters as version 1, retaining
    /// up to `capacity` snapshots (clamped to at least 1). No accounting
    /// pool — use [`Self::new_with_pool`] for tracked retention.
    pub fn new(initial: Vec<Tensor>, capacity: usize) -> Self {
        Self::build(initial, capacity, None)
            .expect("pool-less bus construction cannot fail")
    }

    /// As [`Self::new`], charging retention to `pool` (one buffer per
    /// unique retained shard, freed on eviction).
    ///
    /// A publish charges its new shards *before* evicting the oldest
    /// version, so a bounded pool needs one version's delta of headroom
    /// above steady-state retention or a full-ring publish fails with
    /// [`WeightBusError::PoolExhausted`]. Accounting pools
    /// ([`MemoryPool::unbounded`]) are unaffected.
    pub fn new_with_pool(
        initial: Vec<Tensor>,
        capacity: usize,
        pool: Arc<MemoryPool>,
    ) -> Result<Self, WeightBusError> {
        Self::build(initial, capacity, Some(pool))
    }

    /// Validated construction: rejects a `capacity` below what the
    /// staleness `window` requires (the config/build-time check that
    /// turns a mid-run `Evicted` deep inside the old-logprob stage into
    /// a typed error up front).
    pub fn new_checked(
        initial: Vec<Tensor>,
        capacity: usize,
        window: usize,
        prompts_per_iter: usize,
        pool: Option<Arc<MemoryPool>>,
    ) -> Result<Self, WeightBusError> {
        let required = Self::required_capacity(window, prompts_per_iter);
        if capacity < required {
            return Err(WeightBusError::CapacityBelowWindow { capacity, required, window });
        }
        Self::build(initial, capacity, pool)
    }

    fn build(
        initial: Vec<Tensor>,
        capacity: usize,
        pool: Option<Arc<MemoryPool>>,
    ) -> Result<Self, WeightBusError> {
        let shards: Vec<Arc<WeightShard>> = initial
            .into_iter()
            .enumerate()
            .map(|(i, t)| Arc::new(WeightShard { tensor_idx: i, epoch: 1, data: t }))
            .collect();
        let bus = Self {
            capacity: capacity.max(1),
            pool,
            inner: Mutex::new(Ring::default()),
        };
        {
            let mut g = bus.inner.lock().unwrap();
            bus.insert_version(&mut g, 1, shards)?;
        }
        Ok(bus)
    }

    /// Commit one version: charge the pool for shards not yet retained
    /// (rolled back atomically on exhaustion), bump refcounts, push the
    /// ring entry, and evict beyond capacity (releasing pool charges for
    /// shards no retained version references anymore).
    fn insert_version(
        &self,
        g: &mut Ring,
        version: u64,
        shards: Vec<Arc<WeightShard>>,
    ) -> Result<(), WeightBusError> {
        let mut charged: Vec<ShardKey> = Vec::new();
        for s in &shards {
            let key = s.key();
            if g.retained.contains_key(&key) {
                continue;
            }
            let buffer = match &self.pool {
                Some(pool) => {
                    let label = format!("bus.t{}.e{}", s.tensor_idx, s.epoch);
                    match pool.alloc(label, s.bytes()) {
                        Ok(id) => Some(id),
                        Err(_) => {
                            let err = WeightBusError::PoolExhausted {
                                requested_bytes: s.bytes(),
                                free_bytes: pool.free_bytes(),
                            };
                            for k in charged {
                                if let Some(r) = g.retained.remove(&k) {
                                    g.unique_bytes -= r.bytes;
                                    if let Some(id) = r.buffer {
                                        let freed = pool.free(id);
                                        debug_assert!(freed.is_ok(), "rollback double free");
                                    }
                                }
                            }
                            return Err(err);
                        }
                    }
                }
                None => None,
            };
            g.retained.insert(key, Retained { refs: 0, bytes: s.bytes(), buffer });
            g.unique_bytes += s.bytes();
            charged.push(key);
        }
        for s in &shards {
            g.retained.get_mut(&s.key()).expect("charged above").refs += 1;
        }
        g.peak_unique_bytes = g.peak_unique_bytes.max(g.unique_bytes);
        g.ring.push_back((version, shards));
        while g.ring.len() > self.capacity {
            let (_, old) = g.ring.pop_front().expect("len > capacity >= 1");
            for s in old {
                let key = s.key();
                let gone = {
                    let r = g.retained.get_mut(&key).expect("retained while ringed");
                    r.refs -= 1;
                    r.refs == 0
                };
                if gone {
                    let r = g.retained.remove(&key).unwrap();
                    g.unique_bytes -= r.bytes;
                    if let (Some(pool), Some(id)) = (&self.pool, r.buffer) {
                        // by construction every buffer is freed exactly once
                        let freed = pool.free(id);
                        debug_assert!(freed.is_ok(), "bus shard buffer freed twice");
                    }
                }
            }
        }
        Ok(())
    }

    /// One shard vector for `params`, sharing `head`'s shards where the
    /// content is unchanged and minting epoch-`next` shards elsewhere.
    fn dedup_against(
        head: &[Arc<WeightShard>],
        params: &[Tensor],
        next: u64,
    ) -> Vec<Arc<WeightShard>> {
        params
            .iter()
            .enumerate()
            .map(|(i, t)| {
                if head[i].data == *t {
                    head[i].clone()
                } else {
                    Arc::new(WeightShard { tensor_idx: i, epoch: next, data: t.clone() })
                }
            })
            .collect()
    }

    /// Publish a new snapshot; returns its version. Tensors whose content
    /// is unchanged since the head share the head's shards (no new
    /// bytes); changed tensors mint shards with this version as their
    /// content epoch. Evicts the oldest versions beyond `capacity`.
    ///
    /// The O(model) content compare and the clones of changed tensors run
    /// *outside* the lock (against a head snapshot), so replica refreshes
    /// on the generation hot path only ever block on the ring insert. If
    /// a concurrent publish moves the head in between (multi-publisher
    /// callers only — the executor is single-producer), the dedup redoes
    /// against the live head under the lock.
    pub fn publish(&self, params: &[Tensor]) -> Result<WeightVersion, WeightBusError> {
        let (head_v, head_shards) = {
            let g = self.inner.lock().unwrap();
            let (v, shards) = g.ring.back().expect("bus ring is never empty");
            (*v, shards.clone())
        };
        if params.len() != head_shards.len() {
            return Err(WeightBusError::WrongTensorCount {
                got: params.len(),
                expect: head_shards.len(),
            });
        }
        let next = head_v + 1;
        let shards = Self::dedup_against(&head_shards, params, next);

        let mut g = self.inner.lock().unwrap();
        let live_head = g.ring.back().expect("bus ring is never empty").0;
        if live_head == head_v {
            self.insert_version(&mut g, next, shards)?;
            return Ok(WeightVersion(next));
        }
        // head moved under us: epochs minted against the stale head could
        // collide with the racing publisher's — rebuild under the lock
        let next = live_head + 1;
        let head_shards = g.ring.back().unwrap().1.clone();
        let shards = Self::dedup_against(&head_shards, params, next);
        self.insert_version(&mut g, next, shards)?;
        Ok(WeightVersion(next))
    }

    /// Publish a version from only the tensors that (may have) changed;
    /// unnamed indices inherit the head's shards. Content is still
    /// compared, so passing an unchanged tensor costs no retention. This
    /// is the resharding flow's publish path: the allgather–swap reshard
    /// hands over its changed generation-layout slices without ever
    /// materializing a full snapshot. Returns the minted version and the
    /// bytes of shards this publish actually minted (the retention
    /// delta, computed under the lock — 0 when every passed tensor
    /// matched the head).
    pub fn publish_delta(
        &self,
        changed: &[(usize, Tensor)],
    ) -> Result<(WeightVersion, u64), WeightBusError> {
        let mut g = self.inner.lock().unwrap();
        let head = g.ring.back().expect("bus ring is never empty");
        let next = head.0 + 1;
        let mut shards = head.1.clone();
        let mut minted = 0u64;
        for (i, t) in changed {
            let Some(slot) = shards.get_mut(*i) else {
                return Err(WeightBusError::TensorIndexOutOfRange {
                    index: *i,
                    n_tensors: shards.len(),
                });
            };
            if slot.data != *t {
                minted += t.size_bytes() as u64;
                *slot = Arc::new(WeightShard { tensor_idx: *i, epoch: next, data: t.clone() });
            }
        }
        self.insert_version(&mut g, next, shards)?;
        Ok((WeightVersion(next), minted))
    }

    /// Newest snapshot (as a view) and its version.
    pub fn head(&self) -> (WeightVersion, WeightView) {
        let g = self.inner.lock().unwrap();
        let (v, shards) = g.ring.back().expect("bus ring is never empty");
        (WeightVersion(*v), WeightView { version: WeightVersion(*v), shards: shards.clone() })
    }

    /// Newest version number without cloning any shard handles.
    pub fn head_version(&self) -> WeightVersion {
        WeightVersion(self.inner.lock().unwrap().ring.back().unwrap().0)
    }

    /// Oldest version still retained.
    pub fn oldest(&self) -> WeightVersion {
        WeightVersion(self.inner.lock().unwrap().ring.front().unwrap().0)
    }

    /// Reconstruct a specific retained version as a view over shared
    /// shards — bit-identical to the snapshot that was published.
    pub fn get(&self, version: WeightVersion) -> Result<WeightView, WeightBusError> {
        let g = self.inner.lock().unwrap();
        let oldest = g.ring.front().unwrap().0;
        let newest = g.ring.back().unwrap().0;
        if version.0 > newest {
            return Err(WeightBusError::NotYetPublished { requested: version.0, newest });
        }
        if version.0 < oldest {
            return Err(WeightBusError::Evicted { requested: version.0, oldest, newest });
        }
        // versions are dense and ascending, so the ring indexes directly
        let shards = g.ring[(version.0 - oldest) as usize].1.clone();
        Ok(WeightView { version, shards })
    }

    /// Newest snapshot strictly newer than `seen`, if any (the replica
    /// refresh primitive).
    pub fn newer_than(&self, seen: WeightVersion) -> Option<(WeightVersion, WeightView)> {
        let g = self.inner.lock().unwrap();
        let (v, shards) = g.ring.back().expect("bus ring is never empty");
        if *v > seen.0 {
            Some((
                WeightVersion(*v),
                WeightView { version: WeightVersion(*v), shards: shards.clone() },
            ))
        } else {
            None
        }
    }

    /// Versions currently retained.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().ring.len()
    }

    pub fn is_empty(&self) -> bool {
        false // the ring always holds at least the newest snapshot
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Σ bytes of *unique* retained shards — the bus's actual memory
    /// footprint. Equals the attached pool's live bytes.
    pub fn retained_bytes(&self) -> u64 {
        self.inner.lock().unwrap().unique_bytes
    }

    /// Unique retained shards.
    pub fn retained_shards(&self) -> usize {
        self.inner.lock().unwrap().retained.len()
    }

    /// High-water mark of [`Self::retained_bytes`].
    pub fn peak_retained_bytes(&self) -> u64 {
        self.inner.lock().unwrap().peak_unique_bytes
    }

    /// What PR 2's full-copy retention would hold for the same ring:
    /// Σ over retained versions of their full snapshot bytes.
    pub fn naive_equivalent_bytes(&self) -> u64 {
        self.inner.lock().unwrap().naive_equivalent_bytes()
    }

    /// Snapshot of the retention accounting for reports/benches.
    pub fn retention_stats(&self) -> BusRetention {
        let g = self.inner.lock().unwrap();
        BusRetention {
            versions: g.ring.len(),
            unique_shards: g.retained.len(),
            retained_bytes: g.unique_bytes,
            peak_retained_bytes: g.peak_unique_bytes,
            naive_equivalent_bytes: g.naive_equivalent_bytes(),
        }
    }
}

impl fmt::Debug for WeightBus {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let g = self.inner.lock().unwrap();
        f.debug_struct("WeightBus")
            .field("capacity", &self.capacity)
            .field("oldest", &g.ring.front().unwrap().0)
            .field("newest", &g.ring.back().unwrap().0)
            .field("unique_shards", &g.retained.len())
            .field("retained_bytes", &g.unique_bytes)
            .finish()
    }
}

/// Bytes one materialized replica of `params` holds.
fn params_bytes(params: &[Tensor]) -> u64 {
    params.iter().map(|t| t.size_bytes() as u64).sum()
}

/// Charge one replica snapshot to the accounting pool, mapping pool
/// exhaustion to the bus's typed error.
fn charge_replica(
    pool: &MemoryPool,
    label: String,
    bytes: u64,
) -> Result<BufferId, WeightBusError> {
    pool.alloc(label, bytes).map_err(|_| WeightBusError::PoolExhausted {
        requested_bytes: bytes,
        free_bytes: pool.free_bytes(),
    })
}

/// A stage thread's head-tracking inference replica (used by generation
/// replicas, which always want the freshest weights and stamp what they
/// got). Optionally charged to a tracked [`MemoryPool`], so a run with
/// `N` elastic generation replicas accounts for its `N` materialized
/// weight copies the same way the bus accounts for retention.
pub struct WeightReplica {
    pub version: WeightVersion,
    pub policy: Policy,
    pool: Option<Arc<MemoryPool>>,
    buffer: Option<BufferId>,
    /// pool-charge label prefix (identifies the owning replica in the
    /// pool's live set across refreshes)
    label: String,
}

impl WeightReplica {
    pub fn new(bus: &WeightBus) -> Self {
        let (version, view) = bus.head();
        Self {
            version,
            policy: Policy::from_params(view.to_params()),
            pool: None,
            buffer: None,
            label: String::new(),
        }
    }

    /// As [`Self::new`], charging the materialized snapshot to `pool`
    /// (re-charged on every refresh under the same `label` prefix,
    /// freed on drop).
    pub fn new_with_pool(
        bus: &WeightBus,
        pool: Arc<MemoryPool>,
        label: &str,
    ) -> Result<Self, WeightBusError> {
        let (version, view) = bus.head();
        let params = view.to_params();
        let buffer = charge_replica(&pool, format!("{label}.{version}"), params_bytes(&params))?;
        Ok(Self {
            version,
            policy: Policy::from_params(params),
            pool: Some(pool),
            buffer: Some(buffer),
            label: label.to_string(),
        })
    }

    /// Pick up the newest snapshot if the bus moved; returns whether the
    /// replica changed. Pool-charged replicas swap their charge (free
    /// old, alloc new, same replica label) so the pool's live bytes keep
    /// tracking the materialized copies, attributably.
    pub fn refresh(&mut self, bus: &WeightBus) -> Result<bool, WeightBusError> {
        match bus.newer_than(self.version) {
            Some((version, view)) => {
                let params = view.to_params();
                if let Some(pool) = &self.pool {
                    if let Some(old) = self.buffer.take() {
                        let freed = pool.free(old);
                        debug_assert!(freed.is_ok(), "replica buffer freed twice");
                    }
                    self.buffer = Some(charge_replica(
                        pool,
                        format!("{}.{version}", self.label),
                        params_bytes(&params),
                    )?);
                }
                self.version = version;
                self.policy = Policy::from_params(params);
                Ok(true)
            }
            None => Ok(false),
        }
    }
}

impl Drop for WeightReplica {
    fn drop(&mut self) {
        if let (Some(pool), Some(id)) = (&self.pool, self.buffer.take()) {
            let _ = pool.free(id);
        }
    }
}

/// Small MRU cache of *version-pinned* replicas for the old-logprob
/// stage: claimed batches arrive grouped by stamped version, and
/// adjacent batches usually share a version, so a handful of entries
/// avoids rebuilding a `Policy` (one materialized snapshot) per batch.
/// Each elastic old-logprob replica owns its own cache; attach a pool
/// ([`Self::with_pool`]) and every cached snapshot is charged to it
/// (freed on LRU eviction and on drop), so the run's report covers the
/// replicas' weight memory, not just the bus's.
pub struct ReplicaCache {
    cap: usize,
    /// most-recently-used last
    entries: Vec<(u64, Policy, Option<BufferId>)>,
    pool: Option<Arc<MemoryPool>>,
}

impl ReplicaCache {
    pub fn new(cap: usize) -> Self {
        Self { cap: cap.max(1), entries: Vec::new(), pool: None }
    }

    /// As [`Self::new`], charging every cached replica to `pool`.
    pub fn with_pool(cap: usize, pool: Arc<MemoryPool>) -> Self {
        Self { cap: cap.max(1), entries: Vec::new(), pool: Some(pool) }
    }

    fn evict(&mut self, i: usize) {
        let (_, _, buffer) = self.entries.remove(i);
        if let (Some(pool), Some(id)) = (&self.pool, buffer) {
            let freed = pool.free(id);
            debug_assert!(freed.is_ok(), "replica cache buffer freed twice");
        }
    }

    /// Replica for `version`, built from the bus on a miss. Propagates
    /// the bus's typed error if the version is outside the ring (or the
    /// accounting pool cannot admit the snapshot).
    pub fn get_or_build(
        &mut self,
        bus: &WeightBus,
        version: WeightVersion,
    ) -> Result<&Policy, WeightBusError> {
        if let Some(i) = self.entries.iter().position(|(v, ..)| *v == version.0) {
            let hit = self.entries.remove(i);
            self.entries.push(hit);
        } else {
            let view = bus.get(version)?;
            if self.entries.len() >= self.cap {
                self.evict(0);
            }
            let params = view.to_params();
            let buffer = match &self.pool {
                Some(pool) => Some(charge_replica(
                    pool,
                    format!("replica.cache.{version}"),
                    params_bytes(&params),
                )?),
                None => None,
            };
            self.entries.push((version.0, Policy::from_params(params), buffer));
        }
        Ok(&self.entries.last().unwrap().1)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

impl Drop for ReplicaCache {
    fn drop(&mut self) {
        while !self.entries.is_empty() {
            self.evict(0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(tag: f32) -> Vec<Tensor> {
        vec![Tensor::f32(&[2], vec![tag, tag + 0.5]).unwrap()]
    }

    /// Two tensors so dedup has something to distinguish: tensor 0 varies
    /// with `a`, tensor 1 with `b`.
    fn params2(a: f32, b: f32) -> Vec<Tensor> {
        vec![
            Tensor::f32(&[2], vec![a, a + 0.5]).unwrap(),
            Tensor::f32(&[4], vec![b; 4]).unwrap(),
        ]
    }

    fn tag_of(v: &WeightView) -> f32 {
        v.tensor(0).as_f32().unwrap()[0]
    }

    #[test]
    fn publish_is_monotone_from_one() {
        let bus = WeightBus::new(params(0.0), 4);
        assert_eq!(bus.head_version(), WeightVersion(1));
        for i in 1..=5u64 {
            let v = bus.publish(&params(i as f32)).unwrap();
            assert_eq!(v, WeightVersion(i + 1));
        }
        assert_eq!(bus.head_version(), WeightVersion(6));
    }

    #[test]
    fn get_returns_the_exact_snapshot() {
        let bus = WeightBus::new(params(1.0), 8);
        bus.publish(&params(2.0)).unwrap();
        bus.publish(&params(3.0)).unwrap();
        for v in 1..=3u64 {
            let view = bus.get(WeightVersion(v)).unwrap();
            assert_eq!(tag_of(&view), v as f32);
            assert_eq!(view.version(), WeightVersion(v));
            assert_eq!(view.to_params(), params(v as f32), "view must be bit-identical");
        }
    }

    #[test]
    fn eviction_honours_capacity_and_is_typed() {
        let bus = WeightBus::new(params(1.0), 2);
        bus.publish(&params(2.0)).unwrap();
        bus.publish(&params(3.0)).unwrap(); // evicts v1
        assert_eq!(bus.len(), 2);
        assert_eq!(bus.oldest(), WeightVersion(2));
        match bus.get(WeightVersion(1)) {
            Err(WeightBusError::Evicted { requested: 1, oldest: 2, newest: 3 }) => {}
            other => panic!("expected typed eviction error, got {other:?}"),
        }
        match bus.get(WeightVersion(9)) {
            Err(WeightBusError::NotYetPublished { requested: 9, newest: 3 }) => {}
            other => panic!("expected not-yet-published error, got {other:?}"),
        }
    }

    #[test]
    fn evicted_snapshot_survives_through_existing_arcs() {
        let bus = WeightBus::new(params(1.0), 1);
        let held = bus.get(WeightVersion(1)).unwrap();
        bus.publish(&params(2.0)).unwrap(); // v1 leaves the ring
        assert!(matches!(bus.get(WeightVersion(1)), Err(WeightBusError::Evicted { .. })));
        assert_eq!(tag_of(&held), 1.0, "reader-held view must stay valid");
    }

    #[test]
    fn newer_than_only_reports_progress() {
        let bus = WeightBus::new(params(1.0), 4);
        assert!(bus.newer_than(WeightVersion(1)).is_none());
        bus.publish(&params(2.0)).unwrap();
        let (v, view) = bus.newer_than(WeightVersion(1)).unwrap();
        assert_eq!(v, WeightVersion(2));
        assert_eq!(tag_of(&view), 2.0);
        assert!(bus.newer_than(WeightVersion(2)).is_none());
    }

    #[test]
    fn unchanged_tensors_share_shards() {
        let bus = WeightBus::new(params2(1.0, 10.0), 8);
        let full: u64 = params2(1.0, 10.0).iter().map(|t| t.size_bytes() as u64).sum();
        // change only tensor 0 — tensor 1's shard must be reused
        bus.publish(&params2(2.0, 10.0)).unwrap();
        let (v1, v2) = (bus.get(WeightVersion(1)).unwrap(), bus.get(WeightVersion(2)).unwrap());
        assert!(Arc::ptr_eq(v1.shard(1), v2.shard(1)), "unchanged shard not shared");
        assert!(!Arc::ptr_eq(v1.shard(0), v2.shard(0)), "changed shard wrongly shared");
        assert_eq!(v2.shard(0).epoch, 2);
        assert_eq!(v2.shard(1).epoch, 1);
        // retention: 1 full model + 1 changed shard, not 2 full models
        let t0 = params2(0.0, 0.0)[0].size_bytes() as u64;
        assert_eq!(bus.retained_bytes(), full + t0);
        assert_eq!(bus.retained_shards(), 3);
        assert_eq!(bus.naive_equivalent_bytes(), 2 * full);
        // an identical publish re-shares everything: zero new bytes
        let before = bus.retained_bytes();
        bus.publish(&params2(2.0, 10.0)).unwrap();
        assert_eq!(bus.retained_bytes(), before, "identical publish must cost nothing");
    }

    #[test]
    fn publish_delta_inherits_head() {
        let bus = WeightBus::new(params2(1.0, 10.0), 8);
        let t1 = Tensor::f32(&[4], vec![20.0; 4]).unwrap();
        let (v, minted) = bus.publish_delta(&[(1, t1.clone())]).unwrap();
        assert_eq!(v, WeightVersion(2));
        assert_eq!(minted, t1.size_bytes() as u64, "one changed tensor minted");
        // re-publishing head content mints nothing
        let (_, minted) = bus.publish_delta(&[(1, t1.clone())]).unwrap();
        assert_eq!(minted, 0, "unchanged delta must mint zero bytes");
        let view = bus.get(v).unwrap();
        assert_eq!(view.tensor(0), &params2(1.0, 0.0)[0], "index 0 inherited from head");
        assert_eq!(view.tensor(1), &t1);
        // out-of-range index is a typed error and mints no version
        match bus.publish_delta(&[(7, t1)]) {
            Err(WeightBusError::TensorIndexOutOfRange { index: 7, n_tensors: 2 }) => {}
            other => panic!("expected out-of-range, got {other:?}"),
        }
        assert_eq!(bus.head_version(), WeightVersion(3));
    }

    #[test]
    fn wrong_tensor_count_rejected() {
        let bus = WeightBus::new(params2(1.0, 2.0), 4);
        match bus.publish(&params(1.0)) {
            Err(WeightBusError::WrongTensorCount { got: 1, expect: 2 }) => {}
            other => panic!("expected wrong-count error, got {other:?}"),
        }
    }

    #[test]
    fn capacity_below_window_is_typed_build_error() {
        // the satellite regression: capacity=1 with window 2 would evict
        // still-stamped versions mid-run — must fail at build time
        let required = WeightBus::required_capacity(2, 16);
        match WeightBus::new_checked(params(1.0), 1, 2, 16, None) {
            Err(WeightBusError::CapacityBelowWindow { capacity: 1, required: r, window: 2 }) => {
                assert_eq!(r, required)
            }
            other => panic!("expected CapacityBelowWindow, got {:?}", other.map(|_| ())),
        }
        // exactly the bound builds
        assert!(WeightBus::new_checked(params(1.0), required, 2, 16, None).is_ok());
        assert_eq!(WeightBus::required_capacity(1, 4), 6);
        assert_eq!(WeightBus::required_capacity(2, 16), 50);
    }

    #[test]
    fn pool_charges_track_unique_shard_bytes() {
        let pool = Arc::new(MemoryPool::unbounded("weightbus"));
        let bus =
            WeightBus::new_with_pool(params2(1.0, 10.0), 2, Arc::clone(&pool)).unwrap();
        assert_eq!(pool.live_bytes(), bus.retained_bytes());
        bus.publish(&params2(2.0, 10.0)).unwrap();
        assert_eq!(pool.live_bytes(), bus.retained_bytes());
        bus.publish(&params2(3.0, 11.0)).unwrap(); // evicts v1
        assert_eq!(pool.live_bytes(), bus.retained_bytes());
        bus.publish(&params2(3.0, 11.0)).unwrap(); // evicts v2, dedups fully
        assert_eq!(pool.live_bytes(), bus.retained_bytes());
        assert!(pool.peak_bytes() >= pool.live_bytes());
    }

    #[test]
    fn pool_exhaustion_is_typed_and_rolls_back() {
        let full: u64 = params2(0.0, 0.0).iter().map(|t| t.size_bytes() as u64).sum();
        // room for exactly one full snapshot: the second distinct publish
        // must fail typed, leaving retention untouched
        let pool = Arc::new(MemoryPool::new("tight", full));
        let bus = WeightBus::new_with_pool(params2(1.0, 10.0), 4, Arc::clone(&pool)).unwrap();
        match bus.publish(&params2(2.0, 11.0)) {
            Err(WeightBusError::PoolExhausted { .. }) => {}
            other => panic!("expected PoolExhausted, got {other:?}"),
        }
        assert_eq!(bus.head_version(), WeightVersion(1), "failed publish must not mint");
        assert_eq!(pool.live_bytes(), bus.retained_bytes(), "rollback must balance charges");
    }

    #[test]
    fn replica_views_charge_and_release_the_pool() {
        let bus = WeightBus::new(params(1.0), 8);
        let one = params_bytes(&params(1.0));
        let pool = Arc::new(MemoryPool::unbounded("stage-replicas"));
        // a head-tracking generation replica: one snapshot charged
        let mut rep =
            WeightReplica::new_with_pool(&bus, Arc::clone(&pool), "gen0").unwrap();
        assert_eq!(pool.live_bytes(), one);
        // refresh swaps the charge, never doubles it
        bus.publish(&params(2.0)).unwrap();
        assert!(rep.refresh(&bus).unwrap());
        assert_eq!(pool.live_bytes(), one);
        assert!(!rep.refresh(&bus).unwrap(), "no newer version, no change");
        // a version-pinned cache: one charge per cached entry, LRU
        // eviction releases, drop releases the rest
        {
            let mut cache = ReplicaCache::with_pool(2, Arc::clone(&pool));
            cache.get_or_build(&bus, WeightVersion(1)).unwrap();
            cache.get_or_build(&bus, WeightVersion(2)).unwrap();
            assert_eq!(pool.live_bytes(), 3 * one);
            bus.publish(&params(3.0)).unwrap();
            cache.get_or_build(&bus, WeightVersion(3)).unwrap(); // evicts v1
            assert_eq!(cache.len(), 2);
            assert_eq!(pool.live_bytes(), 3 * one, "eviction must release its charge");
        }
        assert_eq!(pool.live_bytes(), one, "dropping the cache releases every entry");
        drop(rep);
        assert_eq!(pool.live_bytes(), 0, "dropping the replica releases its snapshot");
    }

    #[test]
    fn replica_cache_pins_versions_and_evicts_lru() {
        let bus = WeightBus::new(params(1.0), 8);
        bus.publish(&params(2.0)).unwrap();
        bus.publish(&params(3.0)).unwrap();
        let mut cache = ReplicaCache::new(2);
        let p1 = cache.get_or_build(&bus, WeightVersion(1)).unwrap();
        assert_eq!(p1.params[0].as_f32().unwrap()[0], 1.0);
        cache.get_or_build(&bus, WeightVersion(2)).unwrap();
        assert_eq!(cache.len(), 2);
        // touch v1 so v2 is the LRU, then bring in v3
        cache.get_or_build(&bus, WeightVersion(1)).unwrap();
        cache.get_or_build(&bus, WeightVersion(3)).unwrap();
        assert_eq!(cache.len(), 2);
        // v1 and v3 remain; all resolvable without error
        for (v, tag) in [(1u64, 1.0f32), (3, 3.0)] {
            let p = cache.get_or_build(&bus, WeightVersion(v)).unwrap();
            assert_eq!(p.params[0].as_f32().unwrap()[0], tag);
        }
        // an evicted bus version surfaces the typed error through the cache
        let tight = WeightBus::new(params(1.0), 1);
        tight.publish(&params(2.0)).unwrap();
        let mut c2 = ReplicaCache::new(2);
        assert!(matches!(
            c2.get_or_build(&tight, WeightVersion(1)),
            Err(WeightBusError::Evicted { .. })
        ));
    }
}
