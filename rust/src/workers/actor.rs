//! The actor worker: generation and old-logprob inference states.
//! (The update state lives in `trainers::grpo`, which owns the policy's
//! optimizer loop.)

use anyhow::Result;
use std::sync::Arc;

use crate::generation::{GenEngine, GenRequest};
use crate::runtime::{Engine, Policy, Tensor};
use crate::tokenizer::Tokenizer;
use crate::transfer_dock::{FieldKind, SampleFlow, SampleMeta, Stage};
use crate::util::rng::Rng;

/// Outcome statistics for one generation pass.
#[derive(Debug, Clone, Copy, Default)]
pub struct GenerationOutcome {
    pub sequences: usize,
    pub tokens: u64,
    pub occupancy: f64,
    pub wall_secs: f64,
}

/// The actor worker, bound to a node of the (simulated) cluster.
pub struct ActorWorker {
    pub node: usize,
    pub tokenizer: Tokenizer,
    pub gen_engine: GenEngine,
    pub max_new_tokens: usize,
}

impl ActorWorker {
    pub fn new(
        engine: &Engine,
        node: usize,
        gen_engine: GenEngine,
        max_new_tokens: usize,
    ) -> Self {
        Self { node, tokenizer: Tokenizer::from_manifest(&engine.manifest), gen_engine, max_new_tokens }
    }

    /// Generation state: pull prompt-ready samples, batch-generate, write
    /// tokens + response masks + completion text back. Works over any
    /// [`SampleFlow`] (transfer dock or replay-buffer baseline).
    pub fn run_generation(
        &self,
        engine: &Engine,
        policy: &Policy,
        dock: &dyn SampleFlow,
        rng: &mut Rng,
        max_batch: usize,
    ) -> Result<GenerationOutcome> {
        let metas = dock.request_ready(Stage::Generation, max_batch)?;
        self.generate_claimed(engine, policy, dock, rng, &metas)
    }

    /// Process an already-claimed batch of generation-ready metas (the
    /// pipelined executor's stage loop claims via `wait_ready` and hands
    /// the work here).
    pub fn generate_claimed(
        &self,
        engine: &Engine,
        policy: &Policy,
        dock: &dyn SampleFlow,
        rng: &mut Rng,
        metas: &[SampleMeta],
    ) -> Result<GenerationOutcome> {
        if metas.is_empty() {
            return Ok(GenerationOutcome::default());
        }
        let samples = dock.fetch(self.node, metas)?;
        let mut requests = Vec::with_capacity(samples.len());
        for s in &samples {
            let prompt_ids = self.tokenizer.encode(&s.prompt_text)?;
            requests.push(GenRequest {
                id: s.index,
                prompt_ids,
                max_new_tokens: self.max_new_tokens,
            });
        }
        let (results, stats) = self.gen_engine.generate(engine, policy, requests, rng)?;

        let seq = engine.manifest.artifact("logprobs")?.seq;
        for r in &results {
            let s = samples.iter().find(|s| s.index == r.id).unwrap();
            let prompt_ids = self.tokenizer.encode(&s.prompt_text)?;
            let (tokens, mask, resp_len) =
                pack_sequence(&prompt_ids, &r.response_ids, seq, self.tokenizer.pad_id)?;
            let completion = self.tokenizer.decode(&r.response_ids);
            dock.store_generation(
                self.node,
                r.id,
                vec![
                    (FieldKind::Tokens, tokens),
                    (FieldKind::RespMask, mask),
                ],
                completion,
                resp_len,
            )?;
        }
        Ok(GenerationOutcome {
            sequences: results.len(),
            tokens: stats.tokens_generated,
            occupancy: stats.occupancy,
            wall_secs: stats.wall_secs,
        })
    }

    /// Old-logprob inference state: score response tokens under the
    /// *current* policy before the update changes it.
    pub fn run_old_logprobs(
        &self,
        engine: &Engine,
        policy: &Policy,
        flow: &dyn SampleFlow,
        max_batch: usize,
    ) -> Result<usize> {
        run_logprob_stage(
            engine,
            policy,
            flow,
            &self.tokenizer,
            self.node,
            Stage::OldLogprob,
            FieldKind::OldLp,
            max_batch,
        )
    }

    /// Claimed-batch variant of [`Self::run_old_logprobs`] for the
    /// pipelined executor's stage loop.
    pub fn old_logprobs_claimed(
        &self,
        engine: &Engine,
        policy: &Policy,
        flow: &dyn SampleFlow,
        metas: &[SampleMeta],
    ) -> Result<usize> {
        let a = engine.manifest.artifact("logprobs")?.clone();
        logprob_claimed(
            engine,
            policy,
            flow,
            &self.tokenizer,
            self.node,
            FieldKind::OldLp,
            metas,
            a.batch,
            a.seq,
        )
    }
}

/// Shared implementation for the two logprob-producing stages: claim work
/// in artifact-batch chunks until the stage queue drains.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_logprob_stage(
    engine: &Engine,
    policy: &Policy,
    flow: &dyn SampleFlow,
    tokenizer: &Tokenizer,
    node: usize,
    stage: Stage,
    field: FieldKind,
    max_batch: usize,
) -> Result<usize> {
    let a = engine.manifest.artifact("logprobs")?.clone();
    let (b, s) = (a.batch, a.seq);
    let mut done = 0usize;
    loop {
        let metas: Vec<SampleMeta> = flow.request_ready(stage, b.min(max_batch))?;
        if metas.is_empty() {
            break;
        }
        done += logprob_claimed(engine, policy, flow, tokenizer, node, field, &metas, b, s)?;
    }
    Ok(done)
}

/// Score one already-claimed batch of metas with the logprobs artifact and
/// write `field` back for each sample. Chunks by the artifact batch size.
#[allow(clippy::too_many_arguments)]
pub(crate) fn logprob_claimed(
    engine: &Engine,
    policy: &Policy,
    flow: &dyn SampleFlow,
    tokenizer: &Tokenizer,
    node: usize,
    field: FieldKind,
    metas: &[SampleMeta],
    b: usize,
    s: usize,
) -> Result<usize> {
    let mut done = 0usize;
    for chunk in metas.chunks(b) {
        let samples = flow.fetch(node, chunk)?;
        let refs: Vec<&_> = samples.iter().collect();
        let tokens = super::stack_tokens(tokenizer, &refs, b, s)?;
        let lp = policy.logprobs(engine, &tokens)?;
        let lpv = lp.as_f32()?;
        for (i, sample) in samples.iter().enumerate() {
            let row = lpv[i * (s - 1)..(i + 1) * (s - 1)].to_vec();
            flow.store_fields(
                node,
                sample.index,
                vec![(field, Tensor::f32(&[s - 1], row)?)],
            )?;
            done += 1;
        }
    }
    Ok(done)
}

/// Lay out BOS+prompt+response into the artifact's fixed `[S]` shape and
/// build the response mask `[S-1]` (mask index t scores token t+1).
pub(crate) fn pack_sequence(
    prompt_ids: &[i32],
    response_ids: &[i32],
    seq: usize,
    pad_id: i32,
) -> Result<(Tensor, Tensor, usize)> {
    let mut tokens = prompt_ids.to_vec();
    tokens.extend_from_slice(response_ids);
    anyhow::ensure!(tokens.len() <= seq, "sequence {} exceeds artifact seq {seq}", tokens.len());
    let resp_start = prompt_ids.len();
    let resp_len = response_ids.len();
    tokens.resize(seq, pad_id);
    let mut mask = vec![0f32; seq - 1];
    for t in resp_start - 1..resp_start - 1 + resp_len {
        mask[t] = 1.0;
    }
    Ok((
        Tensor::i32(&[seq], tokens)?,
        Tensor::f32(&[seq - 1], mask)?,
        resp_len,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_sequence_mask_alignment() {
        // prompt [1, 10, 11], response [20, 2]: token positions 3, 4 are
        // response; mask indices 2 and 3 (predicting tokens 3 and 4) set
        let (tokens, mask, resp_len) = pack_sequence(&[1, 10, 11], &[20, 2], 8, 0).unwrap();
        assert_eq!(tokens.as_i32().unwrap(), &[1, 10, 11, 20, 2, 0, 0, 0]);
        assert_eq!(mask.as_f32().unwrap(), &[0.0, 0.0, 1.0, 1.0, 0.0, 0.0, 0.0]);
        assert_eq!(resp_len, 2);
    }

    #[test]
    fn pack_sequence_overflow_rejected() {
        assert!(pack_sequence(&[1; 6], &[2; 6], 8, 0).is_err());
    }

    #[test]
    fn mask_sums_to_resp_len() {
        let (_, mask, resp_len) = pack_sequence(&[1, 3], &[4, 5, 6], 16, 0).unwrap();
        let sum: f32 = mask.as_f32().unwrap().iter().sum();
        assert_eq!(sum as usize, resp_len);
    }
}
