//! The actor worker: generation and old-logprob inference states.
//! (The update state lives in `trainers::grpo`, which owns the policy's
//! optimizer loop.)

use anyhow::Result;
use std::collections::HashMap;

use crate::generation::{GenEngine, GenRequest, GenResult};
use crate::runtime::{Engine, Policy, Tensor};
use crate::tokenizer::Tokenizer;
use crate::transfer_dock::{FieldKind, Sample, SampleFlow, SampleMeta, Segment, Stage};
use crate::util::rng::Rng;

/// Outcome statistics for one generation pass. Occupancy travels as raw
/// slot-step counters so outcomes from differently-sized claims and
/// replicas merge slot-step-weighted, not claim-weighted.
#[derive(Debug, Clone, Copy, Default)]
pub struct GenerationOutcome {
    pub sequences: usize,
    pub tokens: u64,
    /// slot-steps that carried a live sequence
    pub busy_slot_steps: u64,
    /// total slot-steps (busy + idle)
    pub total_slot_steps: u64,
    pub wall_secs: f64,
}

impl GenerationOutcome {
    /// Fraction of slot-steps that carried a live sequence.
    pub fn occupancy(&self) -> f64 {
        if self.total_slot_steps == 0 {
            0.0
        } else {
            self.busy_slot_steps as f64 / self.total_slot_steps as f64
        }
    }

    /// Merge another pass's counters in (slot-step-weighted by construction).
    pub fn absorb(&mut self, other: &GenerationOutcome) {
        self.sequences += other.sequences;
        self.tokens += other.tokens;
        self.busy_slot_steps += other.busy_slot_steps;
        self.total_slot_steps += other.total_slot_steps;
        self.wall_secs += other.wall_secs;
    }
}

/// The actor worker, bound to a node of the (simulated) cluster.
pub struct ActorWorker {
    pub node: usize,
    pub tokenizer: Tokenizer,
    pub gen_engine: GenEngine,
    pub max_new_tokens: usize,
    /// emit per-sample behavior logprobs (`old_lp`) directly from the
    /// generation writeback — the logits are already in hand when
    /// sampling, which turns the old-logprob state into a verify-or-fill
    /// pass instead of a mandatory recompute
    pub emit_logprobs: bool,
}

impl ActorWorker {
    pub fn new(
        engine: &Engine,
        node: usize,
        gen_engine: GenEngine,
        max_new_tokens: usize,
        emit_logprobs: bool,
    ) -> Self {
        Self {
            node,
            tokenizer: Tokenizer::from_manifest(&engine.manifest),
            gen_engine,
            max_new_tokens,
            emit_logprobs,
        }
    }

    /// Generation state: pull prompt-ready samples, batch-generate, write
    /// tokens + response masks + completion text back, stamped with the
    /// behavior-policy weight version the caller generated under. Works
    /// over any [`SampleFlow`] (transfer dock or replay-buffer baseline).
    pub fn run_generation(
        &self,
        engine: &Engine,
        policy: &Policy,
        dock: &dyn SampleFlow,
        rng: &mut Rng,
        max_batch: usize,
        behavior_version: u64,
    ) -> Result<GenerationOutcome> {
        let metas = dock.request_ready(Stage::Generation, max_batch)?;
        self.generate_claimed(engine, policy, dock, rng, &metas, behavior_version)
    }

    /// Process an already-claimed batch of generation-ready metas (the
    /// pipelined executor's stage loop claims via `wait_ready` and hands
    /// the work here). `behavior_version` must name the weight snapshot
    /// `policy` was built from — it is stamped onto every writeback.
    pub fn generate_claimed(
        &self,
        engine: &Engine,
        policy: &Policy,
        dock: &dyn SampleFlow,
        rng: &mut Rng,
        metas: &[SampleMeta],
        behavior_version: u64,
    ) -> Result<GenerationOutcome> {
        if metas.is_empty() {
            return Ok(GenerationOutcome::default());
        }
        // lease-tolerant: a stale claim (reclaimed + retired while this
        // worker was stalled) is skipped, not an error
        let samples = dock.fetch_resident(self.node, metas)?;
        if samples.is_empty() {
            return Ok(GenerationOutcome::default());
        }
        let (requests, prompt_ids_by_id) = self.prepare_requests(&samples)?;
        let (results, stats) = self.gen_engine.generate(engine, policy, requests, rng)?;

        for r in &results {
            let prompt_ids = prompt_ids_by_id
                .get(&r.id)
                .ok_or_else(|| anyhow::anyhow!("generation result for unknown request {}", r.id))?;
            self.store_result(engine, dock, r, prompt_ids, behavior_version)?;
        }
        Ok(GenerationOutcome {
            sequences: results.len(),
            tokens: stats.tokens_generated,
            busy_slot_steps: stats.busy_slot_steps,
            total_slot_steps: stats.total_slot_steps,
            wall_secs: stats.wall_secs,
        })
    }

    /// Encode fetched samples into generation requests. Returns the
    /// requests plus each encoded prompt keyed by request id — the
    /// writeback path reuses the ids instead of re-tokenizing and
    /// linearly re-finding each sample.
    pub fn prepare_requests(
        &self,
        samples: &[Sample],
    ) -> Result<(Vec<GenRequest>, HashMap<u64, Vec<i32>>)> {
        let mut requests = Vec::with_capacity(samples.len());
        let mut prompt_ids_by_id: HashMap<u64, Vec<i32>> =
            HashMap::with_capacity(samples.len());
        for s in samples {
            let prompt_ids = self.tokenizer.encode(&s.prompt_text)?;
            requests.push(GenRequest {
                id: s.index,
                prompt_ids: prompt_ids.clone(),
                max_new_tokens: self.max_new_tokens,
            });
            prompt_ids_by_id.insert(s.index, prompt_ids);
        }
        Ok((requests, prompt_ids_by_id))
    }

    /// Pack one finished sequence and write it back stamped with
    /// `behavior_version`. The batch path loops this over a claim's
    /// results; the streaming scheduler calls it the moment each
    /// sequence retires — the writeback completes the claim, so
    /// retirement is per-sequence, never held for claim-mates.
    pub fn store_result(
        &self,
        engine: &Engine,
        dock: &dyn SampleFlow,
        r: &GenResult,
        prompt_ids: &[i32],
        behavior_version: u64,
    ) -> Result<()> {
        self.store_result_with_segments(engine, dock, r, prompt_ids, behavior_version, Vec::new())
    }

    /// [`Self::store_result`] carrying an explicit behavior-version segment
    /// list — the partial-rollout path's writeback, where a response that
    /// survived preemptions was decoded under more than one weight version
    /// and each span must be scored under its own. An empty list means the
    /// whole response was decoded under `behavior_version` (the store
    /// synthesizes the full-span segment).
    pub fn store_result_with_segments(
        &self,
        engine: &Engine,
        dock: &dyn SampleFlow,
        r: &GenResult,
        prompt_ids: &[i32],
        behavior_version: u64,
        segments: Vec<Segment>,
    ) -> Result<()> {
        let seq = engine.manifest.artifact("logprobs")?.seq;
        let (tokens, mask, resp_len) =
            pack_sequence(prompt_ids, &r.response_ids, seq, self.tokenizer.pad_id)?;
        let completion = self.tokenizer.decode(&r.response_ids);
        let mut fields = vec![(FieldKind::Tokens, tokens), (FieldKind::RespMask, mask)];
        if self.emit_logprobs {
            fields.push((
                FieldKind::OldLp,
                behavior_logprob_row(&r.response_logprobs, prompt_ids.len(), seq)?,
            ));
        }
        dock.store_generation_with_segments(
            self.node,
            r.id,
            fields,
            completion,
            resp_len,
            behavior_version,
            segments,
        )
    }

    /// Old-logprob inference state: fill `old_lp` for every sample still
    /// missing it (with generation-emitted logprobs this finds nothing —
    /// the state degenerates to verify-or-fill).
    pub fn run_old_logprobs(
        &self,
        engine: &Engine,
        policy: &Policy,
        flow: &dyn SampleFlow,
        max_batch: usize,
    ) -> Result<usize> {
        run_logprob_stage(
            engine,
            policy,
            flow,
            &self.tokenizer,
            self.node,
            Stage::OldLogprob,
            FieldKind::OldLp,
            max_batch,
        )
    }

    /// Claimed-batch variant of [`Self::run_old_logprobs`] for the
    /// pipelined executor's stage loop.
    pub fn old_logprobs_claimed(
        &self,
        engine: &Engine,
        policy: &Policy,
        flow: &dyn SampleFlow,
        metas: &[SampleMeta],
    ) -> Result<usize> {
        let a = engine.manifest.artifact("logprobs")?.clone();
        logprob_claimed(
            engine,
            policy,
            flow,
            &self.tokenizer,
            self.node,
            FieldKind::OldLp,
            metas,
            a.batch,
            a.seq,
        )
    }
}

/// Shared implementation for the two logprob-producing stages: claim work
/// in artifact-batch chunks until the stage queue drains.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_logprob_stage(
    engine: &Engine,
    policy: &Policy,
    flow: &dyn SampleFlow,
    tokenizer: &Tokenizer,
    node: usize,
    stage: Stage,
    field: FieldKind,
    max_batch: usize,
) -> Result<usize> {
    let a = engine.manifest.artifact("logprobs")?.clone();
    let (b, s) = (a.batch, a.seq);
    let mut done = 0usize;
    loop {
        let metas: Vec<SampleMeta> = flow.request_ready(stage, b.min(max_batch))?;
        if metas.is_empty() {
            break;
        }
        done += logprob_claimed(engine, policy, flow, tokenizer, node, field, &metas, b, s)?;
    }
    Ok(done)
}

/// Score one already-claimed batch of metas with the logprobs artifact and
/// write `field` back for each sample. Chunks by the artifact batch size.
#[allow(clippy::too_many_arguments)]
pub(crate) fn logprob_claimed(
    engine: &Engine,
    policy: &Policy,
    flow: &dyn SampleFlow,
    tokenizer: &Tokenizer,
    node: usize,
    field: FieldKind,
    metas: &[SampleMeta],
    b: usize,
    s: usize,
) -> Result<usize> {
    let mut done = 0usize;
    for chunk in metas.chunks(b) {
        // lease-tolerant fetch: stale claims in the chunk are skipped
        let samples = flow.fetch_resident(node, chunk)?;
        if samples.is_empty() {
            continue;
        }
        let refs: Vec<&_> = samples.iter().collect();
        let tokens = super::stack_tokens(tokenizer, &refs, b, s)?;
        let lp = policy.logprobs(engine, &tokens)?;
        let lpv = lp.as_f32()?;
        for (i, sample) in samples.iter().enumerate() {
            let row = lpv[i * (s - 1)..(i + 1) * (s - 1)].to_vec();
            flow.store_fields(
                node,
                sample.index,
                vec![(field, Tensor::f32(&[s - 1], row)?)],
            )?;
            done += 1;
        }
    }
    Ok(done)
}

/// Compute the `[S-1]` logprob row for each already-fetched sample under
/// one policy, without writing anything back. The per-segment scoring
/// path uses this to evaluate the same token row under several
/// version-pinned policies and splice each segment's span from the row
/// computed under the version that span was decoded under.
pub(crate) fn logprob_rows_fetched(
    engine: &Engine,
    policy: &Policy,
    tokenizer: &Tokenizer,
    samples: &[&Sample],
    b: usize,
    s: usize,
) -> Result<Vec<Vec<f32>>> {
    let mut rows = Vec::with_capacity(samples.len());
    for chunk in samples.chunks(b) {
        let tokens = super::stack_tokens(tokenizer, chunk, b, s)?;
        let lp = policy.logprobs(engine, &tokens)?;
        let lpv = lp.as_f32()?;
        for i in 0..chunk.len() {
            rows.push(lpv[i * (s - 1)..(i + 1) * (s - 1)].to_vec());
        }
    }
    Ok(rows)
}

/// Lay the generation-time behavior logprobs into the `[S-1]` layout the
/// `logprobs` artifact produces: response token j (sequence position
/// `resp_start + j`) is scored at row index `resp_start - 1 + j`; every
/// non-response position is 0 and masked out of the loss by `resp_mask`.
fn behavior_logprob_row(
    response_logprobs: &[f32],
    resp_start: usize,
    seq: usize,
) -> Result<Tensor> {
    anyhow::ensure!(resp_start >= 1, "response cannot start before position 1 (BOS)");
    anyhow::ensure!(
        resp_start + response_logprobs.len() <= seq,
        "response overruns artifact seq"
    );
    let mut row = vec![0f32; seq - 1];
    for (j, &lp) in response_logprobs.iter().enumerate() {
        row[resp_start - 1 + j] = lp;
    }
    Tensor::f32(&[seq - 1], row)
}

/// Lay out BOS+prompt+response into the artifact's fixed `[S]` shape and
/// build the response mask `[S-1]` (mask index t scores token t+1).
pub(crate) fn pack_sequence(
    prompt_ids: &[i32],
    response_ids: &[i32],
    seq: usize,
    pad_id: i32,
) -> Result<(Tensor, Tensor, usize)> {
    let mut tokens = prompt_ids.to_vec();
    tokens.extend_from_slice(response_ids);
    anyhow::ensure!(tokens.len() <= seq, "sequence {} exceeds artifact seq {seq}", tokens.len());
    let resp_start = prompt_ids.len();
    let resp_len = response_ids.len();
    tokens.resize(seq, pad_id);
    let mut mask = vec![0f32; seq - 1];
    for t in resp_start - 1..resp_start - 1 + resp_len {
        mask[t] = 1.0;
    }
    Ok((
        Tensor::i32(&[seq], tokens)?,
        Tensor::f32(&[seq - 1], mask)?,
        resp_len,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_sequence_mask_alignment() {
        // prompt [1, 10, 11], response [20, 2]: token positions 3, 4 are
        // response; mask indices 2 and 3 (predicting tokens 3 and 4) set
        let (tokens, mask, resp_len) = pack_sequence(&[1, 10, 11], &[20, 2], 8, 0).unwrap();
        assert_eq!(tokens.as_i32().unwrap(), &[1, 10, 11, 20, 2, 0, 0, 0]);
        assert_eq!(mask.as_f32().unwrap(), &[0.0, 0.0, 1.0, 1.0, 0.0, 0.0, 0.0]);
        assert_eq!(resp_len, 2);
    }

    #[test]
    fn pack_sequence_overflow_rejected() {
        assert!(pack_sequence(&[1; 6], &[2; 6], 8, 0).is_err());
    }

    #[test]
    fn mask_sums_to_resp_len() {
        let (_, mask, resp_len) = pack_sequence(&[1, 3], &[4, 5, 6], 16, 0).unwrap();
        let sum: f32 = mask.as_f32().unwrap().iter().sum();
        assert_eq!(sum as usize, resp_len);
    }

    #[test]
    fn behavior_logprobs_land_on_mask_positions() {
        // same layout as pack_sequence_mask_alignment: prompt len 3,
        // response len 2 → mask indices 2 and 3 carry the logprobs
        let (_, mask, _) = pack_sequence(&[1, 10, 11], &[20, 2], 8, 0).unwrap();
        let row = behavior_logprob_row(&[-0.5, -1.25], 3, 8).unwrap();
        let (row, mask) = (row.as_f32().unwrap(), mask.as_f32().unwrap());
        assert_eq!(row, &[0.0, 0.0, -0.5, -1.25, 0.0, 0.0, 0.0]);
        for (t, &m) in mask.iter().enumerate() {
            assert_eq!(m == 1.0, row[t] != 0.0, "mask/logprob disagree at {t}");
        }
    }

    #[test]
    fn behavior_logprob_row_rejects_overrun() {
        assert!(behavior_logprob_row(&[-0.1; 6], 3, 8).is_err());
        assert!(behavior_logprob_row(&[-0.1; 2], 0, 8).is_err());
    }
}
