//! RL workers: the stages of Fig. 1 wired to a [`SampleFlow`].
//!
//! Each worker pulls ready samples from its own TD controller (or from the
//! centralized replay buffer when running the baseline), computes, and
//! writes fields back — the dataflow bytes this generates are the paper's
//! sample flow. The actor has three states (generation / inference /
//! update); reference and reward are separate workers.

mod actor;
mod reference;
mod reward;

pub use actor::{ActorWorker, GenerationOutcome};
pub(crate) use actor::logprob_rows_fetched;
pub use reference::ReferenceWorker;
pub use reward::{RewardOutcome, RewardWorker, ScoredSample};

use anyhow::Result;

use crate::runtime::Tensor;
use crate::tokenizer::Tokenizer;
use crate::transfer_dock::Sample;

/// Shared shaping helpers for inference batches.
pub(crate) fn tokens_row(
    tok: &Tokenizer,
    sample: &Sample,
    seq: usize,
) -> Result<Vec<i32>> {
    let t = sample
        .get(crate::transfer_dock::FieldKind::Tokens)
        .ok_or_else(|| anyhow::anyhow!("sample {} has no tokens", sample.index))?;
    let mut row = t.as_i32()?.to_vec();
    anyhow::ensure!(row.len() <= seq, "sample longer than artifact seq");
    row.resize(seq, tok.pad_id);
    Ok(row)
}

/// Stack sample token rows into a `[B, S]` i32 tensor, padding the last
/// batch with repeats of the final row (extra rows are discarded by the
/// caller).
pub(crate) fn stack_tokens(
    tok: &Tokenizer,
    samples: &[&Sample],
    batch: usize,
    seq: usize,
) -> Result<Tensor> {
    anyhow::ensure!(!samples.is_empty() && samples.len() <= batch);
    let mut data = Vec::with_capacity(batch * seq);
    for s in samples {
        data.extend(tokens_row(tok, s, seq)?);
    }
    let last: Vec<i32> = data[data.len() - seq..].to_vec();
    for _ in samples.len()..batch {
        data.extend(&last);
    }
    Tensor::i32(&[batch, seq], data)
}
