//! Reference worker: frozen-policy log-probs for the KL penalty.

use anyhow::Result;

use crate::runtime::{Engine, Policy};
use crate::tokenizer::Tokenizer;
use crate::transfer_dock::{FieldKind, SampleFlow, SampleMeta, Stage};

/// Holds the frozen reference policy (the pre-RL checkpoint; in this
/// reproduction, the AOT initial parameters).
pub struct ReferenceWorker {
    pub node: usize,
    pub policy: Policy,
    tokenizer: Tokenizer,
}

impl ReferenceWorker {
    pub fn new(engine: &Engine, node: usize) -> Result<Self> {
        Ok(Self {
            node,
            policy: Policy::load_initial(engine, 0.0)?,
            tokenizer: Tokenizer::from_manifest(&engine.manifest),
        })
    }

    /// Inference state: fill `ref_lp` for every ready sample.
    pub fn run(&self, engine: &Engine, flow: &dyn SampleFlow, max_batch: usize) -> Result<usize> {
        super::actor::run_logprob_stage(
            engine,
            &self.policy,
            flow,
            &self.tokenizer,
            self.node,
            Stage::RefLogprob,
            FieldKind::RefLp,
            max_batch,
        )
    }

    /// Claimed-batch variant of [`Self::run`] for the pipelined executor's
    /// stage loop.
    pub fn run_claimed(
        &self,
        engine: &Engine,
        flow: &dyn SampleFlow,
        metas: &[SampleMeta],
    ) -> Result<usize> {
        let a = engine.manifest.artifact("logprobs")?.clone();
        super::actor::logprob_claimed(
            engine,
            &self.policy,
            flow,
            &self.tokenizer,
            self.node,
            FieldKind::RefLp,
            metas,
            a.batch,
            a.seq,
        )
    }
}
