//! Reward worker: rule reward over generated completions.

use anyhow::Result;

use crate::data::{Task, Tier};
use crate::rewards;
use crate::runtime::Tensor;
use crate::transfer_dock::{FieldKind, SampleFlow, SampleMeta, Stage};

/// Stateless rule-reward worker (no model inference).
pub struct RewardWorker {
    pub node: usize,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct RewardOutcome {
    pub scored: usize,
    pub exact: usize,
    pub well_formed: usize,
    pub reward_sum: f32,
}

impl RewardOutcome {
    pub fn absorb(&mut self, s: &ScoredSample) {
        self.scored += 1;
        self.exact += s.exact as usize;
        self.well_formed += s.well_formed as usize;
        self.reward_sum += s.reward;
    }
}

/// One scored sample, with the group id for callers that attribute
/// rewards back to their admission batch.
#[derive(Debug, Clone, Copy)]
pub struct ScoredSample {
    pub index: u64,
    pub group: u64,
    pub reward: f32,
    pub exact: bool,
    pub well_formed: bool,
}

impl RewardWorker {
    pub fn new(node: usize) -> Self {
        Self { node }
    }

    /// Drain every reward-ready sample (sync-mode barrier semantics).
    pub fn run(&self, flow: &dyn SampleFlow, max_batch: usize) -> Result<RewardOutcome> {
        let mut out = RewardOutcome::default();
        loop {
            let metas = flow.request_ready(Stage::Reward, max_batch)?;
            if metas.is_empty() {
                break;
            }
            for s in self.score_claimed(flow, &metas)? {
                out.absorb(&s);
            }
        }
        Ok(out)
    }

    /// Score one already-claimed batch of metas and write the reward field
    /// back for each sample.
    pub fn score_claimed(
        &self,
        flow: &dyn SampleFlow,
        metas: &[SampleMeta],
    ) -> Result<Vec<ScoredSample>> {
        // lease-tolerant fetch: stale claims (reclaimed + retired while
        // this worker was stalled) are skipped, not an error
        let samples = flow.fetch_resident(self.node, metas)?;
        let mut out = Vec::with_capacity(samples.len());
        for s in samples {
            let task = Task {
                prompt: s.prompt_text.clone(),
                answer: s.answer,
                tier: Tier::Easy, // tier is irrelevant for scoring
            };
            let score = rewards::score(&task, &s.completion_text);
            flow.store_fields(
                self.node,
                s.index,
                vec![(FieldKind::Reward, Tensor::scalar_f32(score.reward))],
            )?;
            out.push(ScoredSample {
                index: s.index,
                group: s.group,
                reward: score.reward,
                exact: score.exact,
                well_formed: score.well_formed,
            });
        }
        Ok(out)
    }
}
