//! Reward worker: rule reward over generated completions.

use anyhow::Result;

use crate::data::{Task, Tier};
use crate::rewards;
use crate::runtime::Tensor;
use crate::transfer_dock::{FieldKind, SampleFlow, Stage};

/// Stateless rule-reward worker (no model inference).
pub struct RewardWorker {
    pub node: usize,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct RewardOutcome {
    pub scored: usize,
    pub exact: usize,
    pub well_formed: usize,
    pub reward_sum: f32,
}

impl RewardWorker {
    pub fn new(node: usize) -> Self {
        Self { node }
    }

    pub fn run(&self, flow: &dyn SampleFlow, max_batch: usize) -> Result<RewardOutcome> {
        let mut out = RewardOutcome::default();
        loop {
            let metas = flow.request_ready(Stage::Reward, max_batch)?;
            if metas.is_empty() {
                break;
            }
            let samples = flow.fetch(self.node, &metas)?;
            for s in samples {
                let task = Task {
                    prompt: s.prompt_text.clone(),
                    answer: s.answer,
                    tier: Tier::Easy, // tier is irrelevant for scoring
                };
                let score = rewards::score(&task, &s.completion_text);
                out.scored += 1;
                out.exact += score.exact as usize;
                out.well_formed += score.well_formed as usize;
                out.reward_sum += score.reward;
                flow.store_fields(
                    self.node,
                    s.index,
                    vec![(FieldKind::Reward, Tensor::scalar_f32(score.reward))],
                )?;
            }
        }
        Ok(out)
    }
}
