//! Behavior-policy correctness properties for the versioned weight flow
//! (needs HLO artifacts: `make artifacts`).
//!
//! The pipelined executor stamps every sample with the weight version
//! that generated it and scores old-logprobs under that *recorded*
//! version. This suite pins the three properties the issue demands:
//!
//! (a) every sample's scored `old_lp` matches a from-scratch recompute
//!     under its stamped version — for both the inference-path recompute
//!     and the generation-emitted (`gen_logprobs`) fast path;
//! (b) version lag never exceeds the `max_inflight_iters` staleness
//!     window (and the ring never evicts a live stamp — the run would
//!     fail with a typed error if it did);
//! (c) `sync` mode with stamping is bitwise deterministic per seed, all
//!     stamps within an iteration are equal, and the history/stamping
//!     instrumentation does not perturb training metrics.

use std::sync::{Arc, Mutex};

use mindspeed_rl::runtime::{artifact_dir, Engine, Policy, Tensor};
use mindspeed_rl::tokenizer::Tokenizer;
use mindspeed_rl::trainers::{run_grpo_on_flow, GrpoConfig, PipelineMode};
use mindspeed_rl::transfer_dock::{
    CommLedger, DockTopology, FieldKind, Sample, SampleFlow, SampleMeta, Stage, TransferDock,
};
use mindspeed_rl::weights::WeightVersion;

// ------------------------------------------------- recording flow shim

/// A `SampleFlow` wrapper that captures every retired sample (the full
/// payload, including the stamped version and the scored `old_lp`) so
/// tests can audit what the executor actually trained on.
struct RecordingFlow {
    inner: TransferDock,
    retired: Mutex<Vec<Sample>>,
}

impl RecordingFlow {
    fn new(nodes: usize) -> Self {
        Self {
            inner: TransferDock::new(DockTopology::spread(nodes)),
            retired: Mutex::new(Vec::new()),
        }
    }

    fn retired(&self) -> Vec<Sample> {
        self.retired.lock().unwrap().clone()
    }
}

impl SampleFlow for RecordingFlow {
    fn put_samples(&self, samples: Vec<Sample>) -> anyhow::Result<Vec<u64>> {
        self.inner.put_samples(samples)
    }

    fn request_ready(&self, stage: Stage, max_n: usize) -> anyhow::Result<Vec<SampleMeta>> {
        self.inner.request_ready(stage, max_n)
    }

    fn wait_ready(
        &self,
        stage: Stage,
        max_n: usize,
        timeout: std::time::Duration,
    ) -> anyhow::Result<Vec<SampleMeta>> {
        self.inner.wait_ready(stage, max_n, timeout)
    }

    fn release(&self, stage: Stage, indices: &[u64]) {
        self.inner.release(stage, indices)
    }

    fn tick_lease_clock(&self) -> usize {
        self.inner.tick_lease_clock()
    }

    fn lease_now(&self) -> u64 {
        self.inner.lease_now()
    }

    fn renew(&self, stage: Stage, indices: &[u64]) {
        self.inner.renew(stage, indices)
    }

    fn lease_stats(&self) -> mindspeed_rl::metrics::FlowRecovery {
        self.inner.lease_stats()
    }

    fn fetch(&self, requester_node: usize, metas: &[SampleMeta]) -> anyhow::Result<Vec<Sample>> {
        self.inner.fetch(requester_node, metas)
    }

    fn fetch_resident(
        &self,
        requester_node: usize,
        metas: &[SampleMeta],
    ) -> anyhow::Result<Vec<Sample>> {
        self.inner.fetch_resident(requester_node, metas)
    }

    fn store_fields(
        &self,
        requester_node: usize,
        index: u64,
        fields: Vec<(FieldKind, Tensor)>,
    ) -> anyhow::Result<()> {
        self.inner.store_fields(requester_node, index, fields)
    }

    fn store_generation(
        &self,
        requester_node: usize,
        index: u64,
        fields: Vec<(FieldKind, Tensor)>,
        completion: String,
        resp_len: usize,
        behavior_version: u64,
    ) -> anyhow::Result<()> {
        self.inner
            .store_generation(requester_node, index, fields, completion, resp_len, behavior_version)
    }

    fn retire(&self, index: u64) -> Option<Sample> {
        let out = self.inner.retire(index);
        if let Some(s) = &out {
            self.retired.lock().unwrap().push(s.clone());
        }
        out
    }

    fn ledger(&self) -> CommLedger {
        self.inner.ledger()
    }

    fn shards(&self) -> usize {
        self.inner.shards()
    }

    fn len(&self) -> usize {
        self.inner.len()
    }
}

// --------------------------------------------------------- test helpers

fn base_cfg() -> GrpoConfig {
    GrpoConfig {
        iterations: 3,
        prompts_per_iter: 4,
        group_size: 2,
        max_new_tokens: 4,
        log_every: 0,
        ..Default::default()
    }
}

fn per_run_samples(cfg: &GrpoConfig) -> usize {
    cfg.iterations * cfg.prompts_per_iter * cfg.group_size
}

/// From-scratch `[S-1]` logprob row for one sample under `policy`,
/// through the same `logprobs` artifact the inference stage uses (the
/// sample's token row replicated across the artifact batch — rows are
/// causally independent, so replication does not change row 0).
fn recompute_row(engine: &Engine, policy: &Policy, sample: &Sample) -> Vec<f32> {
    let a = engine.manifest.artifact("logprobs").unwrap();
    let (b, s) = (a.batch, a.seq);
    let tok = Tokenizer::from_manifest(&engine.manifest);
    let mut row = sample.get(FieldKind::Tokens).unwrap().as_i32().unwrap().to_vec();
    assert!(row.len() <= s, "sample row longer than artifact seq");
    row.resize(s, tok.pad_id);
    let mut data = Vec::with_capacity(b * s);
    for _ in 0..b {
        data.extend_from_slice(&row);
    }
    let tokens = Tensor::i32(&[b, s], data).unwrap();
    let lp = policy.logprobs(engine, &tokens).unwrap();
    lp.as_f32().unwrap()[..s - 1].to_vec()
}

// ----------------------------------------------------------- properties

/// (a) With `--pipeline pipelined --max-inflight 2`, every sample's
/// scored old-logprob equals a from-scratch recompute under the weight
/// snapshot its stamp names. The inference-path variant must agree to
/// float-noise tolerance (same artifact, same weights; only the batch
/// composition differs); the generation-emitted variant goes through the
/// incremental decode path, so it gets a looser — but still tight —
/// tolerance.
#[test]
fn old_logprob_matches_recompute_under_stamped_version() {
    let engine = Engine::load(artifact_dir("tiny")).expect("make artifacts first");
    for (label, gen_logprobs, tol) in
        [("recompute-path", false, 1e-4f32), ("gen-emitted", true, 2e-2f32)]
    {
        let cfg = GrpoConfig {
            pipeline: PipelineMode::Pipelined,
            max_inflight_iters: 2,
            gen_logprobs,
            keep_weight_history: true,
            ..base_cfg()
        };
        let flow = Arc::new(RecordingFlow::new(cfg.nodes));
        let report = run_grpo_on_flow(&engine, &cfg, flow.clone()).unwrap();
        let bus = report.weight_history.as_ref().expect("history was requested");
        let retired = flow.retired();
        assert_eq!(retired.len(), per_run_samples(&cfg), "{label}: every sample retires");

        let mut checked_positions = 0usize;
        for smp in &retired {
            assert!(smp.behavior_version >= 1, "{label}: sample {} unstamped", smp.index);
            let view = bus
                .get(WeightVersion(smp.behavior_version))
                .unwrap_or_else(|e| panic!("{label}: stamped snapshot unavailable: {e}"));
            let behavior_policy = Policy::from_params(view.to_params());
            let want = recompute_row(&engine, &behavior_policy, smp);
            let got = smp.get(FieldKind::OldLp).unwrap().as_f32().unwrap();
            let mask = smp.get(FieldKind::RespMask).unwrap().as_f32().unwrap();
            assert_eq!(got.len(), want.len(), "{label}");
            for (t, &m) in mask.iter().enumerate() {
                if m != 1.0 {
                    continue;
                }
                assert!(
                    (got[t] - want[t]).abs() < tol,
                    "{label}: sample {} pos {t}: scored {} vs recompute {} under v{}",
                    smp.index,
                    got[t],
                    want[t],
                    smp.behavior_version
                );
                checked_positions += 1;
            }
        }
        assert!(checked_positions > 0, "{label}: property checked nothing");
    }
}

/// (b) Version lag stays inside the staleness window: with window W and
/// G prompts per iteration, at most (2W−1)×G−1 publishes can land while
/// a sample is in flight (earlier iterations may complete and admit
/// successors up to `k + W − 1`, every publish retires at least one
/// whole group, and the sample's own iteration cannot complete under
/// it). The run itself is the eviction check — a violated window would
/// surface as a typed WeightBusError and fail the executor.
#[test]
fn version_lag_bounded_by_staleness_window() {
    let engine = Engine::load(artifact_dir("tiny")).expect("make artifacts first");
    let cfg = GrpoConfig {
        iterations: 4,
        pipeline: PipelineMode::Pipelined,
        max_inflight_iters: 2,
        ..base_cfg()
    };
    let flow = Arc::new(RecordingFlow::new(cfg.nodes));
    let report = run_grpo_on_flow(&engine, &cfg, flow).unwrap();

    assert_eq!(report.pipeline.version_lag.len(), cfg.iterations);
    for (i, (iter, _)) in report.pipeline.version_lag.iter().enumerate() {
        assert_eq!(*iter, i, "lag entries must finalize in iteration order");
    }
    let total = report.pipeline.lag_total();
    assert_eq!(total.samples as usize, per_run_samples(&cfg), "every sample measured");
    let bound = ((2 * cfg.max_inflight_iters - 1) * cfg.prompts_per_iter + 2) as u64;
    assert!(
        total.max <= bound,
        "worst lag {} publishes exceeds the (2W-1)×G window bound {}",
        total.max,
        bound
    );
}

/// (c) `sync` mode stays the deterministic reference loop: bitwise
/// identical metrics run-to-run for a fixed seed, trivially all-equal
/// stamps (iteration k generates under version k+1), zero recorded lag,
/// and — the pre-change-parity proxy — the stamping/history
/// instrumentation itself does not move a single metric bit.
#[test]
fn sync_mode_bitwise_deterministic_and_trivially_stamped() {
    let engine = Engine::load(artifact_dir("tiny")).expect("make artifacts first");
    let run = |keep_history: bool| {
        let cfg = GrpoConfig { keep_weight_history: keep_history, ..base_cfg() };
        let flow = Arc::new(RecordingFlow::new(cfg.nodes));
        let report = run_grpo_on_flow(&engine, &cfg, flow.clone()).unwrap();
        (report, flow.retired())
    };

    let (a, retired_a) = run(true);
    let (b, _) = run(true);
    let (c, _) = run(false);

    assert_eq!(a.pipeline.mode, "sync");
    for (ma, mb) in a.iterations.iter().zip(&b.iterations) {
        assert_eq!(ma.reward_mean, mb.reward_mean, "reward not bitwise stable");
        assert_eq!(ma.exact_frac, mb.exact_frac);
        assert_eq!(ma.loss, mb.loss, "loss not bitwise stable");
        assert_eq!(ma.kl, mb.kl);
        assert_eq!(ma.ratio, mb.ratio);
    }
    // instrumentation must not perturb training
    for (ma, mc) in a.iterations.iter().zip(&c.iterations) {
        assert_eq!(ma.reward_mean, mc.reward_mean, "history knob changed training");
        assert_eq!(ma.loss, mc.loss);
        assert_eq!(ma.kl, mc.kl);
    }
    assert!(c.weight_history.is_none());

    // trivially-equal stamps: iteration k ran entirely under version k+1
    let cfg = base_cfg();
    assert_eq!(retired_a.len(), per_run_samples(&cfg));
    for smp in &retired_a {
        let iter = smp.group as usize / cfg.prompts_per_iter;
        assert_eq!(
            smp.behavior_version,
            iter as u64 + 1,
            "sync sample {} of iteration {iter} mis-stamped",
            smp.index
        );
    }
    // zero lag, one entry per iteration
    assert_eq!(a.pipeline.version_lag.len(), cfg.iterations);
    let lag = a.pipeline.lag_total();
    assert_eq!((lag.sum, lag.max), (0, 0), "sync lag must be zero by construction");
    assert_eq!(lag.samples as usize, per_run_samples(&cfg));

    // and the history bus holds exactly initial + one publish per iteration
    let bus = a.weight_history.as_ref().unwrap();
    assert_eq!(bus.head_version(), WeightVersion(cfg.iterations as u64 + 1));
}

/// The gen-logprobs fast path folds OldLogprob into Generation: samples
/// arrive with `old_lp` already present, so the old-logprob stage never
/// sees ready work (verify-or-fill with nothing to fill) while training
/// still completes every iteration.
#[test]
fn gen_logprobs_folds_old_logprob_into_generation() {
    let engine = Engine::load(artifact_dir("tiny")).expect("make artifacts first");
    let cfg = GrpoConfig {
        pipeline: PipelineMode::Pipelined,
        max_inflight_iters: 2,
        gen_logprobs: true,
        ..base_cfg()
    };
    let flow = Arc::new(RecordingFlow::new(cfg.nodes));
    let report = run_grpo_on_flow(&engine, &cfg, flow.clone()).unwrap();
    assert_eq!(report.iterations.len(), cfg.iterations);
    for m in &report.iterations {
        assert!(m.loss.is_finite());
    }
    assert_eq!(flow.retired().len(), per_run_samples(&cfg));
    assert!(
        !report.pipeline.busy.contains_key("old_logprob"),
        "old-logprob stage should have had nothing to fill, but booked busy time"
    );
    // the stamped behavior logprobs actually flowed into training
    for smp in flow.retired() {
        assert!(smp.has(FieldKind::OldLp), "sample {} missing gen-emitted old_lp", smp.index);
        assert!(smp.behavior_version >= 1);
    }
}
