//! Chaos suite: lease-based fault recovery for the sample flow.
//!
//! The headline invariants, per the issue's acceptance criteria:
//!
//! 1. **Zero loss** — under seeded worker kill/stall plans, every run
//!    drains to the *same retired-sample set* as a fault-free run (no
//!    sample lost, none double-trained/retired).
//! 2. **Conservation** — per store, bytes admitted == bytes resident +
//!    bytes retired at every quiescent point.
//! 3. **Accounting consistency** — reclaim/redispatch counts in the
//!    recovery report sum consistently with the controllers' attempt
//!    counters (`reclaimed == attempt_bumps`, `redispatched <= reclaimed`).
//! 4. **Differential flow equivalence** — the same seeded workload
//!    through the sync replay-buffer baseline and the pipelined transfer
//!    dock (`max_inflight` 1 and 2) retires identical sample sets.
//!
//! Everything here is artifact-free (it drives the real dock machinery
//! with synthetic stage workers — `sim::chaos`); the one executor-level
//! test self-skips when HLO artifacts are absent. Fixed seeds by
//! default; `CHAOS_RANDOM_SEEDS=1` (the scheduled CI job) appends
//! time-derived seeds for a fuzzing pass.

use mindspeed_rl::sim::chaos::{run_baseline, run_chaos, ChaosConfig, ChaosOutcome};
use mindspeed_rl::trainers::faults::FaultPlan;

fn base_cfg(seed: u64) -> ChaosConfig {
    // the CI chaos jobs run a DOCK_SHARDS ∈ {1, 4} matrix: every test in
    // this suite must hold unchanged at any controller-shard count (the
    // K-vs-K=1 retired-map oracle itself lives in tests/sharded_dock.rs)
    let dock_shards: usize = std::env::var("DOCK_SHARDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);
    let steal_threshold: usize = std::env::var("STEAL_THRESHOLD")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    ChaosConfig {
        iterations: 4,
        prompts_per_iter: 4,
        group_size: 2,
        seed,
        dock_shards: dock_shards.max(1),
        steal_threshold: if dock_shards > 1 { steal_threshold } else { 0 },
        ..Default::default()
    }
}

/// Every invariant a finished run must satisfy, against its fault-free
/// reference.
fn assert_invariants(name: &str, cfg: &ChaosConfig, out: &ChaosOutcome, reference: &ChaosOutcome) {
    assert!(
        out.lossless(cfg),
        "{name}: loss — retired {}/{} resident {} recovery {:?}",
        out.retired.len(),
        cfg.total_samples(),
        out.resident_after,
        out.recovery
    );
    assert_eq!(
        out.retired, reference.retired,
        "{name}: retired set diverged from the fault-free run"
    );
    for (i, c) in out.conservation.iter().enumerate() {
        assert!(c.holds(), "{name}: warehouse {i} violates byte conservation: {c:?}");
        assert_eq!(
            c.admitted_bytes,
            c.retired_bytes + c.resident_bytes,
            "{name}: warehouse {i} admitted != resident + retired"
        );
    }
    let r = &out.recovery;
    assert!(r.consistent(), "{name}: recovery accounting inconsistent: {r:?}");
    assert_eq!(
        r.reclaimed, r.attempt_bumps,
        "{name}: every reclaim must bump exactly one attempt counter"
    );
    assert!(r.redispatched <= r.reclaimed, "{name}: {r:?}");
}

fn chaos_seeds() -> Vec<u64> {
    let mut seeds = vec![11, 42, 1337];
    if std::env::var("CHAOS_RANDOM_SEEDS").as_deref() == Ok("1") {
        let t = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos() as u64;
        for i in 0..3u64 {
            seeds.push(t ^ (i + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        }
        eprintln!("[chaos] randomized-seed mode: {seeds:?}");
    }
    seeds
}

// ------------------------------------------- differential equivalence

/// Satellite 1: the same seeded workload through the sync `ReplayBuffer`
/// baseline and the pipelined `TransferDock` at `max_inflight` 1 and 2
/// retires identical sample sets, and every store conserves bytes.
#[test]
fn differential_flow_equivalence() {
    for seed in [0u64, 7] {
        let sync_rb = run_baseline(&base_cfg(seed)).unwrap();
        assert!(sync_rb.lossless(&base_cfg(seed)));
        for window in [1usize, 2] {
            // generous lease: a fault-free run must not reclaim even if
            // the CI scheduler deschedules a worker briefly
            let cfg = ChaosConfig {
                max_inflight_iters: window,
                lease_ticks: 256,
                ..base_cfg(seed)
            };
            let dock = run_chaos(&cfg).unwrap();
            assert_invariants(&format!("dock w={window} seed={seed}"), &cfg, &dock, &sync_rb);
            assert_eq!(
                dock.recovery.reclaimed, 0,
                "fault-free pipelined run must never reclaim"
            );
        }
    }
}

// ------------------------------------------------------ kill recovery

/// Acceptance criterion: with kill rates > 0 under a seeded `FaultPlan`,
/// the run converges to the fault-free retired set with zero loss, and
/// the recovery report shows nonzero reclaim/redispatch counts that sum
/// consistently with the attempt counters.
#[test]
fn worker_kills_recover_to_identical_retired_set() {
    let cfg = ChaosConfig {
        iterations: 5,
        plan: FaultPlan { seed: 9, kill_rate: 0.4, ..Default::default() },
        ..base_cfg(42)
    };
    // fault-free reference over the same workload shape
    let reference = run_chaos(&ChaosConfig { iterations: 5, ..base_cfg(42) }).unwrap();
    let out = run_chaos(&cfg).unwrap();
    assert_invariants("kills", &cfg, &out, &reference);
    assert!(out.recovery.kills > 0, "plan must fire: {:?}", out.recovery);
    assert!(out.recovery.reclaimed > 0, "kills must surface as lease reclaims");
    assert!(out.recovery.redispatched > 0, "reclaimed samples must be redispatched");
    assert_eq!(out.recovery.restarts, out.recovery.kills, "every kill restarts its stage");
}

// ----------------------------------------------------- stall recovery

/// Stalled workers outlive their lease: claims are reclaimed, a peer
/// worker re-processes them, and the late writebacks are dropped as
/// superseded duplicates — still zero loss, still the same retired set.
#[test]
fn worker_stalls_recover_with_late_writebacks_dropped() {
    let cfg = ChaosConfig {
        iterations: 5,
        workers_per_stage: 2,
        lease_ticks: 3,
        plan: FaultPlan { seed: 21, stall_rate: 0.4, stall_ticks: 10, ..Default::default() },
        ..base_cfg(11)
    };
    let reference =
        run_chaos(&ChaosConfig { iterations: 5, workers_per_stage: 2, ..base_cfg(11) }).unwrap();
    let out = run_chaos(&cfg).unwrap();
    assert_invariants("stalls", &cfg, &out, &reference);
    assert!(out.recovery.stalls > 0, "plan must fire: {:?}", out.recovery);
    assert!(
        out.recovery.reclaimed > 0,
        "a stall past the lease must surface as reclaims: {:?}",
        out.recovery
    );
}

// ------------------------------------------------------- mixed sweep

/// Mixed kills + stalls across several seeds (plus env-gated random
/// seeds for scheduled CI): the invariants hold for every schedule.
#[test]
fn mixed_fault_sweep_across_seeds() {
    for seed in chaos_seeds() {
        let cfg = ChaosConfig {
            workers_per_stage: 2,
            plan: FaultPlan {
                seed: seed ^ 0xdead_beef,
                kill_rate: 0.2,
                stall_rate: 0.2,
                stall_ticks: 8,
                ..Default::default()
            },
            ..base_cfg(seed)
        };
        let reference =
            run_chaos(&ChaosConfig { workers_per_stage: 2, ..base_cfg(seed) }).unwrap();
        let out = run_chaos(&cfg).unwrap();
        assert_invariants(&format!("mixed seed={seed}"), &cfg, &out, &reference);
    }
}

/// The fault schedule is a pure function of the plan seed: two runs with
/// the same plan inject the same per-stage decision streams (the paper's
/// determinism requirement for debugging 384-NPU failures).
#[test]
fn fault_schedules_are_deterministic() {
    use mindspeed_rl::transfer_dock::Stage;
    let plan = FaultPlan { seed: 33, kill_rate: 0.3, stall_rate: 0.3, ..Default::default() };
    for stage in Stage::ALL {
        let a: Vec<_> = (0..200).map(|s| plan.decide_at(stage, s)).collect();
        let b: Vec<_> = (0..200).map(|s| plan.decide_at(stage, s)).collect();
        assert_eq!(a, b);
    }
}

// -------------------------------------- deterministic late-writeback

/// Single-threaded, fully deterministic reclaim → redispatch → late
/// writeback interleaving against the dock (no scheduler involved): the
/// late writer's stale store is dropped and counted, the redispatcher's
/// result stands, nothing is lost.
#[test]
fn late_writeback_after_reclaim_is_superseded_deterministically() {
    use mindspeed_rl::runtime::Tensor;
    use mindspeed_rl::transfer_dock::{
        DockTopology, FieldKind, SampleFlow, Stage, TransferDock,
    };

    let d = TransferDock::with_lease(DockTopology::spread(2), 2);
    let idx = d
        .put_samples(vec![mindspeed_rl::transfer_dock::Sample::new_prompt(
            u64::MAX,
            0,
            "1+1=".into(),
            2,
        )])
        .unwrap()[0];
    // worker A claims generation, then goes silent
    let claim_a = d.request_ready(Stage::Generation, 1).unwrap();
    assert_eq!(claim_a.len(), 1);
    // two idle ticks: A's lease expires, the sample is reclaimed
    d.tick_lease_clock();
    assert_eq!(d.tick_lease_clock(), 1);
    // worker B redispatches and completes generation
    let claim_b = d.request_ready(Stage::Generation, 1).unwrap();
    assert_eq!(claim_b.len(), 1, "reclaimed sample must redispatch");
    d.store_generation(
        0,
        idx,
        vec![(FieldKind::Tokens, Tensor::i32(&[4], vec![1; 4]).unwrap())],
        "b-wins".into(),
        1,
        3,
    )
    .unwrap();
    // A wakes up and writes back late: dropped, stamp and tokens intact
    d.store_generation(
        0,
        idx,
        vec![(FieldKind::Tokens, Tensor::i32(&[4], vec![9; 4]).unwrap())],
        "a-late".into(),
        1,
        8,
    )
    .unwrap();
    let s = d.fetch(0, &d.request_ready(Stage::Reward, 1).unwrap()).unwrap();
    assert_eq!(s[0].completion_text, "b-wins");
    assert_eq!(s[0].behavior_version, 3, "stamp must be immutable after the first write");
    let rec = d.lease_stats();
    assert_eq!(rec.reclaimed, 1);
    assert_eq!(rec.redispatched, 1);
    assert_eq!(rec.superseded_writebacks, 1);
    assert!(rec.consistent());
    for c in d.conservation() {
        assert!(c.holds(), "{c:?}");
    }
}

// ------------------------------------------------- executor (gated)

/// Executor-level acceptance: `run_grpo` in pipelined mode under a
/// seeded fault plan completes every iteration with finite losses and a
/// recovery report whose reclaim/redispatch counts are nonzero and
/// consistent. Needs HLO artifacts; skips with a message otherwise.
#[test]
fn pipelined_executor_survives_chaos() {
    use mindspeed_rl::runtime::{artifact_dir, Engine};
    use mindspeed_rl::trainers::{run_grpo, GrpoConfig, PipelineMode};

    let Ok(engine) = Engine::load(artifact_dir("tiny")) else {
        eprintln!("[chaos] skipping executor test: run `make artifacts` first");
        return;
    };
    let cfg = GrpoConfig {
        iterations: 3,
        prompts_per_iter: 4,
        group_size: 2,
        max_new_tokens: 4,
        pipeline: PipelineMode::Pipelined,
        max_inflight_iters: 2,
        lease_ticks: 4,
        chaos_kill_rate: 0.3,
        chaos_stall_rate: 0.2,
        chaos_stall_ticks: 8,
        chaos_seed: 5,
        log_every: 0,
        ..Default::default()
    };
    let report = run_grpo(&engine, &cfg).unwrap();
    assert_eq!(report.iterations.len(), 3, "every iteration must complete under faults");
    for m in &report.iterations {
        assert!(m.loss.is_finite());
        assert!(m.reward_mean >= 0.0 && m.reward_mean <= 1.0);
    }
    let rec = &report.pipeline.recovery;
    assert!(rec.consistent(), "{rec:?}");
    assert!(
        rec.kills + rec.stalls > 0,
        "fault plan must fire at these rates: {rec:?}"
    );
    assert!(rec.reclaimed > 0, "faults must surface as reclaims: {rec:?}");
    assert!(rec.redispatched > 0, "reclaimed work must be redispatched: {rec:?}");
    assert_eq!(rec.restarts, rec.kills);
    // no sample lost: the per-iteration metrics each cover the full
    // G × N sample count (reward means over n samples) — and the summary
    // line advertises the recovery
    assert!(report.summary().contains("recovery["), "{}", report.summary());
}
