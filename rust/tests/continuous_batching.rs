//! Streaming generation (continuous batching): the differential suite.
//!
//! The headline invariant, in the style of `tests/elastic_scaling.rs`:
//! for ANY admission interleaving the streaming scheduler produces —
//! per-sequence retirement, step-granularity claims, long-tail per-
//! sequence decode budgets — the run retires the **identical sample set
//! with identical behavior-version stamps** as the batch-decode run at
//! the same seed. The harness's synthetic generation makes tokens and
//! stamps pure functions of the prompt, so a scheduler that loses,
//! duplicates, or re-generates a sequence under a different identity
//! shows up as a set or stamp mismatch here.
//!
//! Also pinned: streaming composes with the chaos machinery (kills
//! abandon the whole slot set and the lease brings every sequence
//! back), with elastic gen replicas, and with the autoscaler.

use mindspeed_rl::sim::chaos::{run_baseline, run_chaos, ChaosConfig, ChaosOutcome};
use mindspeed_rl::trainers::faults::FaultPlan;
use mindspeed_rl::trainers::{AutoscaleConfig, StageReplicas};

fn base_cfg(seed: u64) -> ChaosConfig {
    ChaosConfig {
        iterations: 4,
        prompts_per_iter: 4,
        group_size: 2,
        // generous lease: fault-free runs must not reclaim even when the
        // CI scheduler deschedules a worker briefly
        lease_ticks: 256,
        seed,
        ..Default::default()
    }
}

fn streaming_cfg(seed: u64) -> ChaosConfig {
    ChaosConfig { gen_streaming: true, ..base_cfg(seed) }
}

fn assert_equivalent(name: &str, cfg: &ChaosConfig, out: &ChaosOutcome, reference: &ChaosOutcome) {
    assert!(
        out.lossless(cfg),
        "{name}: loss — retired {}/{} resident {} recovery {:?}",
        out.retired.len(),
        cfg.total_samples(),
        out.resident_after,
        out.recovery
    );
    assert_eq!(
        out.retired, reference.retired,
        "{name}: retired set or behavior-version stamps diverged from batch mode"
    );
    for c in &out.conservation {
        assert!(c.holds(), "{name}: byte conservation violated: {c:?}");
    }
    assert!(out.recovery.consistent(), "{name}: {:?}", out.recovery);
}

// ----------------------------------------------- streaming vs batch

/// Acceptance criterion: the streaming drain retires the identical
/// `(set, stamps)` as the batch-decode drain AND the centralized
/// replay-buffer baseline at the same seed — admission timing and
/// per-sequence retirement are invisible to the dataflow.
#[test]
fn streaming_is_stamp_identical_to_batch_decode() {
    for seed in [0u64, 7, 42] {
        let batch = run_chaos(&base_cfg(seed)).unwrap();
        assert!(batch.lossless(&base_cfg(seed)));
        let cfg = streaming_cfg(seed);
        let out = run_chaos(&cfg).unwrap();
        assert_equivalent(&format!("streaming seed={seed}"), &cfg, &out, &batch);
        assert_eq!(
            out.recovery.reclaimed, 0,
            "seed={seed}: fault-free streaming must never trip a lease \
             (renewal every decode step)"
        );
        // and the centralized baseline agrees with both
        let rb = run_baseline(&base_cfg(seed)).unwrap();
        assert_eq!(batch.retired, rb.retired);
    }
}

// ------------------------------------------------- chaos composition

/// Streaming composes with fault injection: a kill abandons the whole
/// slot set mid-decode (held sequences included), a stall parks the
/// worker past its lease — either way every sequence comes back through
/// the lease and the retired `(set, stamps)` still equals batch mode's.
#[test]
fn streaming_and_chaos_compose_losslessly() {
    for seed in [0u64, 7, 42] {
        let reference = run_chaos(&base_cfg(seed)).unwrap();
        let cfg = ChaosConfig {
            lease_ticks: 4,
            plan: FaultPlan {
                seed: seed ^ 0xe1a5,
                kill_rate: 0.25,
                stall_rate: 0.15,
                stall_ticks: 8,
                ..Default::default()
            },
            ..streaming_cfg(seed)
        };
        let out = run_chaos(&cfg).unwrap();
        assert_equivalent(&format!("streaming+chaos seed={seed}"), &cfg, &out, &reference);
    }
    // and at an aggressive kill rate the plan actually fires
    let seed = 42u64;
    let cfg = ChaosConfig {
        iterations: 5,
        lease_ticks: 4,
        plan: FaultPlan { seed: seed ^ 0xbeef, kill_rate: 0.35, ..Default::default() },
        ..streaming_cfg(seed)
    };
    let reference = run_chaos(&ChaosConfig { iterations: 5, ..base_cfg(seed) }).unwrap();
    let out = run_chaos(&cfg).unwrap();
    assert_equivalent("streaming+kills", &cfg, &out, &reference);
    assert!(
        out.recovery.kills > 0,
        "plan must fire at this rate: {:?}",
        out.recovery
    );
}

// --------------------------------------------- elastic composition

/// Streaming composes with elastic gen replicas and with the
/// autoscaler: N concurrent streaming sessions pulling from the same
/// dock partition the workload arbitrarily, yet the retired
/// `(set, stamps)` is unchanged.
#[test]
fn streaming_replicas_and_autoscale_are_stamp_identical() {
    for seed in [0u64, 7] {
        let single = run_chaos(&base_cfg(seed)).unwrap();
        for spec in ["gen=2", "gen=4,logprob=2"] {
            let cfg = ChaosConfig {
                stage_replicas: Some(StageReplicas::parse(spec).unwrap()),
                ..streaming_cfg(seed)
            };
            let out = run_chaos(&cfg).unwrap();
            assert_equivalent(&format!("streaming {spec} seed={seed}"), &cfg, &out, &single);
            assert_eq!(
                out.recovery.reclaimed, 0,
                "{spec}: fault-free streaming replicas must never trip a lease"
            );
        }
        let cfg = ChaosConfig {
            iterations: 6,
            autoscale: Some(AutoscaleConfig {
                min_replicas: 1,
                max_replicas: 4,
                backlog_hi: 2,
                backlog_lo: 0,
                up_ticks: 1,
                down_ticks: 2,
            }),
            ..streaming_cfg(seed)
        };
        let reference = run_chaos(&ChaosConfig { iterations: 6, ..base_cfg(seed) }).unwrap();
        let out = run_chaos(&cfg).unwrap();
        assert_equivalent(&format!("streaming+autoscale seed={seed}"), &cfg, &out, &reference);
    }
}

/// Everything at once: streaming + replicas + chaos. The lease
/// machinery, the replica machinery, and the streaming scheduler are
/// the same dataflow — composition must stay lossless.
#[test]
fn streaming_replicas_and_chaos_compose_losslessly() {
    let seed = 11u64;
    let reference = run_chaos(&ChaosConfig {
        iterations: 5,
        stage_replicas: Some(StageReplicas::uniform(2)),
        ..base_cfg(seed)
    })
    .unwrap();
    let cfg = ChaosConfig {
        iterations: 5,
        stage_replicas: Some(StageReplicas::uniform(2)),
        lease_ticks: 4,
        plan: FaultPlan {
            seed: seed ^ 0xe1a5,
            kill_rate: 0.25,
            stall_rate: 0.15,
            stall_ticks: 8,
            ..Default::default()
        },
        ..streaming_cfg(seed)
    };
    let out = run_chaos(&cfg).unwrap();
    assert_equivalent("streaming+replicas+chaos", &cfg, &out, &reference);
    assert!(
        out.recovery.kills + out.recovery.stalls > 0,
        "plan must fire at these rates: {:?}",
        out.recovery
    );
}

// ------------------------------------------------- executor (gated)

/// Executor-level acceptance: `run_grpo` in pipelined mode with
/// `--gen-streaming` completes every iteration with finite losses, the
/// stream report records occupancy/TTFT/retirement, and the paged KV
/// accounting never deferred (the pool is sized for the full slot set's
/// worst case) and drained back to baseline (the report absorbs each
/// session only after its idle-point invariant checks passed). Needs
/// HLO artifacts; skips with a message otherwise.
#[test]
fn pipelined_executor_runs_streaming_generation() {
    use mindspeed_rl::runtime::{artifact_dir, Engine};
    use mindspeed_rl::trainers::{run_grpo, GrpoConfig, PipelineMode};

    let Ok(engine) = Engine::load(artifact_dir("tiny")) else {
        eprintln!("[streaming] skipping executor test: run `make artifacts` first");
        return;
    };
    let cfg = GrpoConfig {
        iterations: 3,
        prompts_per_iter: 4,
        group_size: 2,
        max_new_tokens: 4,
        pipeline: PipelineMode::Pipelined,
        max_inflight_iters: 2,
        log_every: 0,
        gen_streaming: true,
        prefill_chunk: 2,
        kv_block_tokens: 8,
        ..Default::default()
    };
    let report = run_grpo(&engine, &cfg).unwrap();
    assert_eq!(report.iterations.len(), 3, "every iteration must finalize");
    for m in &report.iterations {
        assert!(m.loss.is_finite());
        assert!(m.reward_mean >= 0.0 && m.reward_mean <= 1.0);
    }
    let gs = &report.pipeline.gen_stream;
    assert!(gs.active(), "streaming run must record a stream report: {gs:?}");
    assert_eq!(
        gs.retired as usize,
        cfg.iterations * cfg.prompts_per_iter * cfg.group_size,
        "every admitted sequence retires through the streaming session: {gs:?}"
    );
    assert_eq!(gs.admitted, gs.retired, "admission/retirement must balance: {gs:?}");
    let occ = gs.occupancy();
    assert!((0.0..=1.0).contains(&occ), "occupancy {occ} outside [0,1]");
    assert!(gs.decode_calls >= gs.steps, "chunked prefill: micro-calls >= steps: {gs:?}");
    assert_eq!(
        gs.kv_deferrals, 0,
        "pool sized for the full slot set must never defer: {gs:?}"
    );
    assert!(report.pipeline.recovery.consistent());

    // and the batch-decode pipelined run still works next to it
    let batch = run_grpo(&engine, &GrpoConfig { gen_streaming: false, ..cfg }).unwrap();
    assert_eq!(batch.iterations.len(), 3);
    assert!(!batch.pipeline.gen_stream.active(), "batch mode must not record stream stats");
}
