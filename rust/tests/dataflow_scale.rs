//! Scale-regime integration test: the transfer dock must beat the
//! centralized replay buffer on implied dispatch time once workers are
//! spread across many nodes and the offered load is realistic — the
//! paper's core claim, exercised on the REAL data structures.

use mindspeed_rl::runtime::Tensor;
use mindspeed_rl::transfer_dock::{
    DockTopology, FieldKind, NetworkModel, ReplayBuffer, Sample, SampleFlow, Stage,
    TransferDock,
};

fn drive(flow: &dyn SampleFlow, nodes: usize, n: usize, elems: usize) -> f64 {
    let samples: Vec<Sample> = (0..n)
        .map(|i| Sample::new_prompt(u64::MAX, i as u64 / 16, format!("{i}+1="), 1))
        .collect();
    let idx = flow.put_samples(samples).unwrap();
    let metas = flow.request_ready(Stage::Generation, n).unwrap();
    for (i, m) in metas.iter().enumerate() {
        let _ = flow.fetch(i % nodes, &[*m]).unwrap();
    }
    for (i, &ix) in idx.iter().enumerate() {
        flow.store_generation(
            i % nodes,
            ix,
            vec![(FieldKind::Tokens, Tensor::i32(&[elems], vec![1; elems]).unwrap())],
            "1".into(),
            2,
            1,
        )
        .unwrap();
    }
    // inference stages fetch from spread workers and write back
    for stage in [Stage::OldLogprob, Stage::RefLogprob] {
        let metas = flow.request_ready(stage, n).unwrap();
        for (i, m) in metas.iter().enumerate() {
            let _ = flow.fetch(i % nodes, &[*m]).unwrap();
        }
        let field = if stage == Stage::OldLogprob { FieldKind::OldLp } else { FieldKind::RefLp };
        for (i, &ix) in idx.iter().enumerate() {
            flow.store_fields(i % nodes, ix, vec![(field, Tensor::zeros(&[elems - 1]))])
                .unwrap();
        }
    }
    for &ix in &idx {
        flow.retire(ix);
    }
    flow.dispatch_secs(&NetworkModel::paper())
}

#[test]
fn dock_beats_replay_buffer_at_scale() {
    let nodes = 16;
    let n = 64 * nodes; // the paper's Fig. 9 offered load
    let elems = 2048;
    let dock = TransferDock::new(DockTopology::spread(nodes));
    let d = drive(&dock, nodes, n, elems);
    let rb = ReplayBuffer::new(0);
    let r = drive(&rb, nodes, n, elems);
    assert!(
        d < r / 2.0,
        "at {nodes} nodes / {n} samples the dock must dispatch >2x faster: dock={d:.3}s rb={r:.3}s"
    );
}

#[test]
fn dock_dispatch_flat_under_weak_scaling() {
    // per-sample dispatch cost must stay ~constant as nodes and load grow
    let mut per_sample = Vec::new();
    for nodes in [4usize, 16] {
        let n = 64 * nodes;
        let dock = TransferDock::new(DockTopology::spread(nodes));
        let d = drive(&dock, nodes, n, 1024);
        per_sample.push(d / n as f64);
    }
    let growth = per_sample[1] / per_sample[0];
    assert!(growth < 1.6, "dock per-sample dispatch grew {growth:.2}x under weak scaling");
}

#[test]
fn replay_buffer_congests_superlinearly() {
    let mut per_sample = Vec::new();
    for nodes in [4usize, 16] {
        let n = 64 * nodes;
        let rb = ReplayBuffer::new(0);
        let d = drive(&rb, nodes, n, 1024);
        per_sample.push(d / n as f64);
    }
    assert!(
        per_sample[1] > per_sample[0],
        "central store per-sample dispatch must grow with cluster size"
    );
}

#[test]
fn warehouses_stay_balanced() {
    let nodes = 8;
    let dock = TransferDock::new(DockTopology::spread(nodes));
    let samples: Vec<Sample> = (0..640)
        .map(|i| Sample::new_prompt(u64::MAX, i as u64 / 8, format!("{i}+2="), 2))
        .collect();
    dock.put_samples(samples).unwrap();
    let (total, max_one) = dock.residency();
    // perfect round-robin: no warehouse holds more than 1/nodes + epsilon
    assert!(max_one as f64 <= total as f64 / nodes as f64 * 1.05);
}
