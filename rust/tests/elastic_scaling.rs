//! Elastic data-parallel stage replicas: the differential suite.
//!
//! The headline invariant, in the style of `tests/chaos.rs`: for ANY
//! replica configuration and ANY autoscale schedule, the run retires the
//! **identical sample set with identical behavior-version stamps** as
//! the single-replica run at the same seed. The harness's synthetic
//! generation makes stamps a pure function of the sample, so a replica
//! or autoscaler that loses, duplicates, or re-generates work under a
//! different identity shows up as a set or stamp mismatch here.
//!
//! Also pinned: drain-then-retire scale-down never abandons a live
//! lease (a fault-free autoscaled run reclaims nothing), and elasticity
//! composes with the chaos machinery (replicas + kills/stalls still
//! converge losslessly).

use mindspeed_rl::sim::chaos::{run_baseline, run_chaos, ChaosConfig, ChaosOutcome};
use mindspeed_rl::trainers::faults::FaultPlan;
use mindspeed_rl::trainers::{AutoscaleConfig, StageReplicas};

fn base_cfg(seed: u64) -> ChaosConfig {
    ChaosConfig {
        iterations: 4,
        prompts_per_iter: 4,
        group_size: 2,
        // generous lease: fault-free runs must not reclaim even when the
        // CI scheduler deschedules a worker briefly
        lease_ticks: 256,
        seed,
        ..Default::default()
    }
}

fn assert_equivalent(name: &str, cfg: &ChaosConfig, out: &ChaosOutcome, reference: &ChaosOutcome) {
    assert!(
        out.lossless(cfg),
        "{name}: loss — retired {}/{} resident {} recovery {:?}",
        out.retired.len(),
        cfg.total_samples(),
        out.resident_after,
        out.recovery
    );
    assert_eq!(
        out.retired, reference.retired,
        "{name}: retired set or behavior-version stamps diverged from the \
         single-replica run"
    );
    for c in &out.conservation {
        assert!(c.holds(), "{name}: byte conservation violated: {c:?}");
    }
    assert!(out.recovery.consistent(), "{name}: {:?}", out.recovery);
}

// --------------------------------------------- static replica configs

/// Acceptance criterion: `--stage-replicas gen=4,logprob=2` (and other
/// shapes) retire the identical `(set, stamps)` as the single-replica
/// run at the same seed.
#[test]
fn replica_configs_are_stamp_identical_to_single_replica() {
    for seed in [0u64, 7, 42] {
        let single = run_chaos(&base_cfg(seed)).unwrap();
        assert!(single.lossless(&base_cfg(seed)));
        for spec in ["gen=4,logprob=2", "gen=2,ref=3,reward=2", "gen=4,logprob=4,ref=4,reward=4"] {
            let cfg = ChaosConfig {
                stage_replicas: Some(StageReplicas::parse(spec).unwrap()),
                ..base_cfg(seed)
            };
            let out = run_chaos(&cfg).unwrap();
            assert_equivalent(&format!("{spec} seed={seed}"), &cfg, &out, &single);
            assert_eq!(
                out.recovery.reclaimed, 0,
                "{spec}: fault-free replicas must never trip a lease"
            );
        }
        // and the centralized baseline agrees with all of them
        let rb = run_baseline(&base_cfg(seed)).unwrap();
        assert_eq!(single.retired, rb.retired);
    }
}

// ------------------------------------------------- autoscale schedule

/// Acceptance criterion: with `--autoscale` under a tick-driven
/// schedule, the retired `(set, stamps)` still equals the
/// single-replica run's — whatever grow/shrink decisions fired — and
/// drain-then-retire scale-down never abandons a live lease (zero
/// reclaims without faults).
#[test]
fn autoscaled_run_is_stamp_identical_and_never_abandons_leases() {
    for seed in [3u64, 11] {
        let single = run_chaos(&base_cfg(seed)).unwrap();
        // aggressive knobs so decisions actually fire during the short
        // drain: scale up after 1 over-backlog tick, down after 2 idle
        let cfg = ChaosConfig {
            iterations: 6,
            autoscale: Some(AutoscaleConfig {
                min_replicas: 1,
                max_replicas: 4,
                backlog_hi: 2,
                backlog_lo: 0,
                up_ticks: 1,
                down_ticks: 2,
            }),
            ..base_cfg(seed)
        };
        let single6 = run_chaos(&ChaosConfig { iterations: 6, ..base_cfg(seed) }).unwrap();
        let out = run_chaos(&cfg).unwrap();
        assert_equivalent(&format!("autoscale seed={seed}"), &cfg, &out, &single6);
        assert_eq!(
            out.recovery.reclaimed, 0,
            "drain-then-retire must never abandon a live lease: {:?}",
            out.recovery
        );
        // the scaling report is recorded for every pull-driven stage,
        // replica counts stayed inside the configured bounds, and the
        // short 4-iteration reference also matches on its prefix shape
        for stage in ["generation", "old_logprob", "ref_logprob", "reward"] {
            let s = &out.scaling.stages[stage];
            assert!(s.max_replicas >= 1 && s.max_replicas <= 4, "{stage}: {s:?}");
            assert!(s.final_replicas >= 1, "{stage}: {s:?}");
            assert_eq!(
                s.timeline.len() as u64,
                s.grows + s.shrinks,
                "{stage}: one timeline entry per applied decision: {s:?}"
            );
            // the autoscaler observes every stage on every idle-pass
            // tick, no more and no less
            assert_eq!(s.obs, out.ticks, "{stage}: one observation per tick");
        }
        // the 4-iteration single-replica run ran the same per-sample
        // pipeline: the 6-iteration retired map extends it
        assert!(single.retired.iter().all(|(k, v)| out.retired.get(k) == Some(v)));
    }
}

/// Elasticity composes with fault injection: replicated stages under a
/// seeded kill/stall plan still converge to the fault-free retired set
/// with zero loss (the lease machinery and the replica machinery are
/// the same machinery).
#[test]
fn replicas_and_chaos_compose_losslessly() {
    let seed = 42u64;
    let reference = run_chaos(&ChaosConfig {
        iterations: 5,
        stage_replicas: Some(StageReplicas::uniform(2)),
        ..base_cfg(seed)
    })
    .unwrap();
    let cfg = ChaosConfig {
        iterations: 5,
        stage_replicas: Some(StageReplicas::uniform(2)),
        lease_ticks: 4,
        plan: FaultPlan {
            seed: seed ^ 0xe1a5,
            kill_rate: 0.25,
            stall_rate: 0.15,
            stall_ticks: 8,
            ..Default::default()
        },
        ..base_cfg(seed)
    };
    let out = run_chaos(&cfg).unwrap();
    assert_equivalent("replicas+chaos", &cfg, &out, &reference);
    assert!(
        out.recovery.kills + out.recovery.stalls > 0,
        "plan must fire at these rates: {:?}",
        out.recovery
    );
}

/// Autoscaling under faults: grow/shrink decisions interleaved with
/// kills and reclaims still lose nothing.
#[test]
fn autoscale_and_chaos_compose_losslessly() {
    let seed = 9u64;
    let reference = run_chaos(&ChaosConfig { iterations: 5, ..base_cfg(seed) }).unwrap();
    let cfg = ChaosConfig {
        iterations: 5,
        lease_ticks: 4,
        autoscale: Some(AutoscaleConfig {
            min_replicas: 1,
            max_replicas: 3,
            backlog_hi: 2,
            backlog_lo: 0,
            up_ticks: 1,
            down_ticks: 2,
        }),
        plan: FaultPlan { seed: seed ^ 0xface, kill_rate: 0.3, ..Default::default() },
        ..base_cfg(seed)
    };
    let out = run_chaos(&cfg).unwrap();
    assert_equivalent("autoscale+chaos", &cfg, &out, &reference);
}

// ------------------------------------------------- executor (gated)

/// Executor-level acceptance: `run_grpo` in pipelined mode with
/// `--stage-replicas gen=4,logprob=2` — and again with `--autoscale` —
/// completes every iteration with finite losses, full sample counts,
/// replica-aware utilization inside [0, 1], and a scaling report.
/// Needs HLO artifacts; skips with a message otherwise.
#[test]
fn pipelined_executor_runs_with_replicas_and_autoscale() {
    use mindspeed_rl::runtime::{artifact_dir, Engine};
    use mindspeed_rl::trainers::{run_grpo, GrpoConfig, PipelineMode};

    let Ok(engine) = Engine::load(artifact_dir("tiny")) else {
        eprintln!("[elastic] skipping executor test: run `make artifacts` first");
        return;
    };
    let base = GrpoConfig {
        iterations: 3,
        prompts_per_iter: 4,
        group_size: 2,
        max_new_tokens: 4,
        pipeline: PipelineMode::Pipelined,
        max_inflight_iters: 2,
        log_every: 0,
        ..Default::default()
    };
    let replicated = GrpoConfig {
        stage_replicas: StageReplicas::parse("gen=4,logprob=2").unwrap(),
        ..base.clone()
    };
    let autoscaled = GrpoConfig {
        autoscale: true,
        autoscale_max: 3,
        autoscale_backlog_hi: 4,
        autoscale_up_ticks: 1,
        ..base.clone()
    };
    for (name, cfg) in [("replicated", replicated), ("autoscaled", autoscaled)] {
        let report = run_grpo(&engine, &cfg).unwrap();
        assert_eq!(report.iterations.len(), 3, "{name}: every iteration must finalize");
        for m in &report.iterations {
            assert!(m.loss.is_finite(), "{name}");
            assert!(m.reward_mean >= 0.0 && m.reward_mean <= 1.0, "{name}");
        }
        // replica-aware utilization: in [0,1] for every recorded stage
        for stage in ["generation", "old_logprob", "ref_logprob", "reward"] {
            let u = report.pipeline.utilization(stage);
            assert!(
                (0.0..=1.0).contains(&u),
                "{name}: utilization({stage}) = {u} outside [0,1]"
            );
        }
        let scaling = &report.pipeline.scaling;
        assert!(
            !scaling.stages.is_empty(),
            "{name}: elastic runs must record a scaling report"
        );
        assert!(report.pipeline.recovery.consistent(), "{name}");
    }
}
