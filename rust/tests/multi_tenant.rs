//! Multi-tenant differential oracle (`--tenants`, `--tenant-weight`,
//! `--tenant-quota-mb`).
//!
//! The tentpole invariant: for ANY weight/quota schedule, the union of
//! per-tenant retired maps of a shared run equals the maps of the same
//! tenants run isolated (`tenant_filter` admits only one tenant's groups
//! while consuming the identical task stream). Weighted-fair handout and
//! quota backpressure are *scheduling* choices — they may reorder
//! admission and claims, never change what gets trained. Stamps are
//! compared too: a re-weighted or deferred sample must retire with the
//! same behavior-version stamp.
//!
//! Sample indices are assigned in admission order, so they legitimately
//! differ between shared and isolated runs — the oracle compares
//! group-keyed views `group → (members, prompt, stamp)` per tenant.
//!
//! Composed with chaos kills/stalls, K ∈ {1, 4} controller shards (the
//! CI `DOCK_SHARDS` matrix), streaming generation, and resumable partial
//! rollouts. Fixed seeds by default; `CHAOS_RANDOM_SEEDS=1` (the
//! scheduled CI job) appends time-derived seeds, printing a
//! `[multi-tenant]` marker line the workflow greps for.

use std::collections::BTreeMap;

use mindspeed_rl::sim::chaos::{run_chaos, ChaosConfig, ChaosOutcome};
use mindspeed_rl::trainers::faults::FaultPlan;

fn base_cfg(seed: u64) -> ChaosConfig {
    // the CI chaos jobs run a DOCK_SHARDS ∈ {1, 4} matrix: the tenant
    // oracle must hold unchanged at any controller-shard count
    let dock_shards: usize = std::env::var("DOCK_SHARDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);
    ChaosConfig {
        iterations: 4,
        prompts_per_iter: 4,
        group_size: 2,
        seed,
        tenants: 2,
        dock_shards: dock_shards.max(1),
        steal_threshold: if dock_shards > 1 { 1 } else { 0 },
        ..Default::default()
    }
}

fn seeds() -> Vec<u64> {
    let mut seeds = vec![5, 42];
    if std::env::var("CHAOS_RANDOM_SEEDS").as_deref() == Ok("1") {
        let t = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos() as u64;
        for i in 0..2u64 {
            seeds.push(t ^ (i + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        }
        eprintln!("[multi-tenant] randomized-seed mode: {seeds:?}");
    }
    seeds
}

/// Per-tenant view of a retired map: group → (members, prompt, stamp),
/// asserting along the way that every member of a group agrees on the
/// prompt and the stamp (a group is one GRPO advantage-normalization
/// unit — tenancy must never split or mix one).
fn tenant_view(
    out: &ChaosOutcome,
    cfg: &ChaosConfig,
    tenant: u32,
) -> BTreeMap<u64, (usize, String, u64)> {
    let mut view: BTreeMap<u64, (usize, String, u64)> = BTreeMap::new();
    for (group, prompt, stamp) in out.retired.values() {
        if cfg.tenant_of_group(*group) != tenant {
            continue;
        }
        let e = view.entry(*group).or_insert_with(|| (0, prompt.clone(), *stamp));
        e.0 += 1;
        assert_eq!(&e.1, prompt, "group {group}: members disagree on the prompt");
        assert_eq!(e.2, *stamp, "group {group}: members disagree on the stamp");
    }
    view
}

/// The oracle proper: the shared run is lossless, and each tenant's
/// group-keyed slice of it equals a fault-free isolated run that admits
/// only that tenant's groups. Weights/quotas/faults are stripped from
/// the isolated runs — they are the clean-room reference.
fn assert_tenant_oracle(name: &str, cfg: &ChaosConfig, out: &ChaosOutcome) {
    assert!(
        out.lossless(cfg),
        "{name}: loss — retired {}/{} resident {} recovery {:?}",
        out.retired.len(),
        cfg.total_samples(),
        out.resident_after,
        out.recovery
    );
    let r = &out.recovery;
    assert!(r.consistent(), "{name}: recovery accounting inconsistent: {r:?}");
    assert_eq!(r.reclaimed, r.attempt_bumps, "{name}: {r:?}");
    for t in 0..cfg.tenants as u32 {
        let iso_cfg = ChaosConfig {
            tenant_filter: Some(t),
            lease_ticks: 256,
            plan: FaultPlan::default(),
            ..cfg.clone()
        };
        let iso = run_chaos(&iso_cfg).unwrap();
        assert!(
            iso.lossless(&iso_cfg),
            "{name}: isolated run for tenant {t} lost samples: {:?}",
            iso.recovery
        );
        assert_eq!(
            tenant_view(out, cfg, t),
            tenant_view(&iso, &iso_cfg, t),
            "{name}: tenant {t}'s shared-run slice diverged from its isolated run \
             (set, counts, prompts, or stamps)"
        );
    }
}

// ----------------------------------------------------- schedule sweeps

/// Any weight schedule, fault-free: weighted-fair arbitration reorders
/// claims, never the per-tenant outcome. Includes a 3-tenant roster —
/// striping and DRR must compose beyond the pairwise case.
#[test]
fn any_weight_schedule_matches_isolated_slices() {
    for seed in seeds() {
        for (tenants, weights) in [
            (2usize, vec![]),
            (2, vec![3, 1]),
            (2, vec![7, 1]),
            (3, vec![1, 2, 3]),
        ] {
            let cfg = ChaosConfig {
                lease_ticks: 256,
                workers_per_stage: 2,
                tenants,
                tenant_weights: weights.clone(),
                ..base_cfg(seed)
            };
            let out = run_chaos(&cfg).unwrap();
            assert_tenant_oracle(&format!("weights={weights:?} seed={seed}"), &cfg, &out);
            assert_eq!(
                out.recovery.reclaimed, 0,
                "weights={weights:?} seed={seed}: fault-free run must not reclaim"
            );
        }
    }
}

/// Any quota schedule: backpressure parks an over-quota tenant's
/// admissions in its FIFO and re-admits as retires uncharge — deferrals
/// must actually fire, siblings must not lose anything, and the views
/// still match the (uncapped) isolated runs.
#[test]
fn any_quota_schedule_only_reorders_admission() {
    for (quota_mb, must_defer) in [(vec![1], true), (vec![1, 1], true), (vec![64], false)] {
        let cfg = ChaosConfig {
            iterations: 8,
            // a window wide enough to outrun a 1 MiB (16-sample) quota
            max_inflight_iters: 8,
            lease_ticks: 256,
            tenant_weights: vec![3, 1],
            tenant_quota_mb: quota_mb.clone(),
            ..base_cfg(42)
        };
        let out = run_chaos(&cfg).unwrap();
        assert_tenant_oracle(&format!("quota={quota_mb:?}"), &cfg, &out);
        if must_defer {
            assert!(
                out.tenant_deferrals > 0,
                "quota={quota_mb:?}: a 1 MiB cap under an 8-iteration window must defer"
            );
        } else {
            assert_eq!(
                out.tenant_deferrals, 0,
                "quota={quota_mb:?}: a 64 MiB cap must never defer this workload"
            );
        }
    }
}

// ------------------------------------------------------ chaos composed

/// Worker kills under a weighted schedule: reclaimed claims redispatch
/// across tenants without mixing them — the per-tenant views converge
/// to the isolated runs.
#[test]
fn kills_compose_with_weighted_tenants() {
    let cfg = ChaosConfig {
        iterations: 5,
        lease_ticks: 4,
        tenant_weights: vec![3, 1],
        plan: FaultPlan { seed: 9, kill_rate: 0.4, ..Default::default() },
        ..base_cfg(42)
    };
    let out = run_chaos(&cfg).unwrap();
    assert_tenant_oracle("kills w=3:1", &cfg, &out);
    assert!(out.recovery.kills > 0, "plan must fire: {:?}", out.recovery);
    assert!(out.recovery.reclaimed > 0, "kills must surface as reclaims");
}

/// Stalls with twin replicas + quota backpressure: the zombie's late
/// writebacks drop as superseded, the quota FIFO re-admits in order,
/// and the tenant views are unchanged.
#[test]
fn stalls_and_quotas_compose() {
    let cfg = ChaosConfig {
        iterations: 8,
        max_inflight_iters: 8,
        workers_per_stage: 2,
        lease_ticks: 3,
        tenant_quota_mb: vec![1, 1],
        plan: FaultPlan { seed: 21, stall_rate: 0.4, stall_ticks: 10, ..Default::default() },
        ..base_cfg(11)
    };
    let out = run_chaos(&cfg).unwrap();
    assert_tenant_oracle("stalls+quotas", &cfg, &out);
    assert!(out.recovery.stalls > 0, "plan must fire: {:?}", out.recovery);
    assert!(out.tenant_deferrals > 0, "quota must bite under the wide window");
}

/// Streaming generation + partial rollouts + kills under a weighted
/// quota'd schedule: killed sequences persist tenant-tagged prefixes,
/// resume (possibly under a different claim), and each tenant's retired
/// view — stamps included — still equals its isolated run.
#[test]
fn streaming_partial_rollouts_survive_kills_per_tenant() {
    for k in [1usize, 4] {
        let cfg = ChaosConfig {
            lease_ticks: 4,
            gen_streaming: true,
            partial_rollouts: true,
            tenant_weights: vec![3, 1],
            dock_shards: k,
            steal_threshold: if k > 1 { 1 } else { 0 },
            plan: FaultPlan { seed: 0xc4a0_5, kill_rate: 0.3, ..Default::default() },
            ..base_cfg(3)
        };
        let out = run_chaos(&cfg).unwrap();
        assert_tenant_oracle(&format!("streaming+partial K={k}"), &cfg, &out);
    }
}

// -------------------------------------------------- randomized matrix

/// The fuzz hook the scheduled CI job leans on: mixed kills + stalls
/// over weighted, quota'd, streaming multi-tenant runs across the seed
/// list (fixed, plus time-derived under `CHAOS_RANDOM_SEEDS=1`).
#[test]
fn mixed_fault_sweep_holds_the_tenant_oracle_across_seeds() {
    for seed in seeds() {
        let cfg = ChaosConfig {
            iterations: 5,
            workers_per_stage: 2,
            gen_streaming: true,
            partial_rollouts: true,
            tenant_weights: vec![2, 1],
            plan: FaultPlan {
                seed: seed ^ 0xdead_beef,
                kill_rate: 0.2,
                stall_rate: 0.2,
                stall_ticks: 8,
                ..Default::default()
            },
            ..base_cfg(seed)
        };
        let out = run_chaos(&cfg).unwrap();
        assert_tenant_oracle(&format!("mixed seed={seed}"), &cfg, &out);
    }
}
