//! Partial rollouts: resumable streaming generation through the sample
//! flow.
//!
//! The headline invariants, per the issue's acceptance criteria:
//!
//! 1. **Oracle equivalence** — a streaming run with kills and resumes
//!    retires the *same sample set with the same behavior stamps* as the
//!    batch-mode replay-buffer oracle: resuming from a persisted prefix
//!    is observationally identical to regenerating from scratch.
//! 2. **Bounded recompute** — decode steps beyond the workload's
//!    intrinsic budget are bounded by the persist cadence: a resumer
//!    replays at most the steps decoded since the abandoned sequence's
//!    last persisted segment.
//! 3. **Prefix fidelity** — a reclaimed sample carries its persisted
//!    prefix to the next claimant; the final writeback supersedes the
//!    prefix and stamps the authoritative segment list.
//!
//! Everything but the one executor-level test is artifact-free (the
//! `sim::chaos` harness drives the real dock machinery with synthetic
//! workers). Fixed seeds by default; `CHAOS_RANDOM_SEEDS=1` (the
//! scheduled CI job) appends time-derived seeds for a fuzzing pass.

use mindspeed_rl::sim::chaos::{
    run_baseline, run_chaos, ChaosConfig, SYNTH_CKPT_STEPS,
};
use mindspeed_rl::trainers::faults::FaultPlan;

fn partial_cfg(seed: u64) -> ChaosConfig {
    ChaosConfig {
        iterations: 5,
        prompts_per_iter: 4,
        group_size: 2,
        gen_streaming: true,
        partial_rollouts: true,
        seed,
        ..Default::default()
    }
}

fn chaos_seeds() -> Vec<u64> {
    let mut seeds = vec![3, 42, 1337];
    if std::env::var("CHAOS_RANDOM_SEEDS").as_deref() == Ok("1") {
        let t = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos() as u64;
        for i in 0..3u64 {
            seeds.push(t ^ (i + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        }
        eprintln!("[partial-rollouts] randomized-seed mode: {seeds:?}");
    }
    seeds
}

// ----------------------------------------------- oracle equivalence

/// Fixed seed, aggressive kills: the streaming run persists prefixes,
/// resumes them after lease reclaim, and still retires the identical
/// `(set, stamps)` the batch-mode oracle produces — with real resume
/// traffic (not a degenerate no-kill schedule) and a recompute total
/// within the checkpoint bound.
#[test]
fn resumed_streaming_run_matches_the_batch_oracle() {
    let cfg = ChaosConfig {
        plan: FaultPlan { seed: 7, kill_rate: 0.4, ..Default::default() },
        ..partial_cfg(42)
    };
    let out = run_chaos(&cfg).unwrap();
    let oracle = run_baseline(&cfg).unwrap();
    assert!(out.lossless(&cfg), "{:?}", out.recovery);
    assert_eq!(
        out.retired, oracle.retired,
        "resuming from persisted prefixes changed the retired set or the stamps"
    );
    assert!(out.recovery.kills > 0, "plan must actually fire: {:?}", out.recovery);
    assert!(out.work.persists > 0, "kills must persist prefixes: {:?}", out.work);
    assert!(out.work.resumes > 0, "reclaimed prefixes must resume: {:?}", out.work);
    assert!(out.work.saved_steps > 0, "resumes must skip persisted work: {:?}", out.work);
    assert!(
        out.work.recomputed_steps() <= out.recovery.reclaimed * SYNTH_CKPT_STEPS,
        "recompute {} exceeds the checkpoint bound (reclaimed={}, cadence={}): {:?}",
        out.work.recomputed_steps(),
        out.recovery.reclaimed,
        SYNTH_CKPT_STEPS,
        out.work
    );
}

/// The same differential across several seeds (plus env-gated random
/// seeds for the scheduled fuzz job): zero loss, identical stamps, and
/// the recompute bound at every schedule.
#[test]
fn partial_rollout_sweep_across_seeds() {
    for seed in chaos_seeds() {
        let cfg = ChaosConfig {
            plan: FaultPlan {
                seed: seed ^ 0x9a17_1a1,
                kill_rate: 0.3,
                ..Default::default()
            },
            ..partial_cfg(seed)
        };
        let out = run_chaos(&cfg).unwrap();
        let oracle = run_baseline(&cfg).unwrap();
        assert!(out.lossless(&cfg), "seed {seed}: {:?}", out.recovery);
        assert_eq!(out.retired, oracle.retired, "seed {seed}: differential diverged");
        assert!(
            out.work.recomputed_steps() <= out.recovery.reclaimed * SYNTH_CKPT_STEPS,
            "seed {seed}: recompute {} vs reclaimed {} (work {:?})",
            out.work.recomputed_steps(),
            out.recovery.reclaimed,
            out.work
        );
    }
}

// ------------------------------------------------- prefix fidelity

/// Single-threaded, fully deterministic claim → persist → lease expiry →
/// redispatch interleaving against the real dock: the next claimant
/// fetches the persisted prefix verbatim, a late shorter checkpoint is
/// dropped (longest-prefix-wins), and the final writeback supersedes the
/// prefix while stamping the authoritative segment list.
#[test]
fn reclaimed_sample_carries_its_persisted_prefix() {
    use mindspeed_rl::runtime::Tensor;
    use mindspeed_rl::transfer_dock::{
        push_segment, DockTopology, FieldKind, PartialRollout, Sample, SampleFlow, Stage,
        TransferDock,
    };

    let d = TransferDock::with_lease(DockTopology::spread(2), 2);
    let idx = d
        .put_samples(vec![Sample::new_prompt(u64::MAX, 0, "1+1=".into(), 2)])
        .unwrap()[0];
    // worker A claims, decodes three tokens, persists the prefix, dies
    let claim_a = d.request_ready(Stage::Generation, 1).unwrap();
    assert_eq!(claim_a.len(), 1);
    let mut segments = Vec::new();
    push_segment(&mut segments, 0, 3, 7);
    d.store_partial_generation(
        0,
        idx,
        PartialRollout {
            response_ids: vec![5, 6, 7],
            response_logprobs: vec![-0.1, -0.2, -0.3],
            segments,
        },
    )
    .unwrap();
    // a late, shorter checkpoint (a slower duplicate writer) must not
    // shrink the persisted prefix
    let mut short = Vec::new();
    push_segment(&mut short, 0, 1, 7);
    d.store_partial_generation(
        0,
        idx,
        PartialRollout {
            response_ids: vec![5],
            response_logprobs: vec![-0.1],
            segments: short,
        },
    )
    .unwrap();
    // two idle ticks: A's lease expires, the sample is reclaimed
    d.tick_lease_clock();
    assert_eq!(d.tick_lease_clock(), 1);
    // worker B redispatches and sees the three-token prefix verbatim
    let claim_b = d.request_ready(Stage::Generation, 1).unwrap();
    assert_eq!(claim_b.len(), 1, "expired claim must redispatch");
    let s = d.fetch_resident(0, &claim_b).unwrap();
    let p = s[0].partial.as_ref().expect("the prefix must survive the reclaim");
    assert_eq!(p.response_ids, vec![5, 6, 7]);
    assert_eq!(p.response_logprobs, vec![-0.1, -0.2, -0.3]);
    assert_eq!(p.segments.len(), 1);
    assert_eq!((p.segments[0].start, p.segments[0].len, p.segments[0].version), (0, 3, 7));
    assert_eq!(
        d.lease_stats().superseded_writebacks,
        1,
        "the shorter late checkpoint must be dropped and counted"
    );
    // B finishes: the completed response supersedes the prefix and
    // stamps the full-span segment
    d.store_generation(
        0,
        idx,
        vec![(FieldKind::Tokens, Tensor::i32(&[4], vec![1; 4]).unwrap())],
        "done".into(),
        2,
        9,
    )
    .unwrap();
    let fin = d.fetch(0, &d.request_ready(Stage::Reward, 1).unwrap()).unwrap();
    assert!(fin[0].partial.is_none(), "completion must clear the persisted prefix");
    assert_eq!(fin[0].segments.len(), 1);
    assert_eq!(
        (fin[0].segments[0].start, fin[0].segments[0].len, fin[0].segments[0].version),
        (0, 2, 9)
    );
    for c in d.conservation() {
        assert!(c.holds(), "{c:?}");
    }
}

// ------------------------------------------------- executor (gated)

/// Executor-level acceptance: `run_grpo` with `--gen-streaming
/// --partial-rollouts --preempt-on-publish` under a seeded kill plan
/// completes every iteration with finite losses and consistent recovery
/// accounting. Needs HLO artifacts; skips with a message otherwise.
#[test]
fn pipelined_executor_with_partial_rollouts_survives_chaos() {
    use mindspeed_rl::runtime::{artifact_dir, Engine};
    use mindspeed_rl::trainers::{run_grpo, GrpoConfig, PipelineMode};

    let Ok(engine) = Engine::load(artifact_dir("tiny")) else {
        eprintln!("[partial-rollouts] skipping executor test: run `make artifacts` first");
        return;
    };
    let cfg = GrpoConfig {
        iterations: 3,
        prompts_per_iter: 4,
        group_size: 2,
        max_new_tokens: 4,
        pipeline: PipelineMode::Pipelined,
        max_inflight_iters: 2,
        lease_ticks: 4,
        gen_streaming: true,
        partial_rollouts: true,
        preempt_on_publish: true,
        chaos_kill_rate: 0.3,
        chaos_seed: 5,
        log_every: 0,
        ..Default::default()
    };
    let report = run_grpo(&engine, &cfg).unwrap();
    assert_eq!(report.iterations.len(), 3, "every iteration must complete under faults");
    for m in &report.iterations {
        assert!(m.loss.is_finite());
    }
    let rec = &report.pipeline.recovery;
    assert!(rec.consistent(), "{rec:?}");
    // the persisted/resumed ledger only shows up once something was
    // actually abandoned; when it does, the summary must advertise it
    let pr = &report.pipeline.partial;
    if pr.active() {
        assert!(report.summary().contains("partial["), "{}", report.summary());
    }
}
