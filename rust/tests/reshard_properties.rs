//! Property tests (seeded-random, proptest-style) on the resharding flow:
//! for arbitrary valid layout pairs, allgather-swap must produce gen
//! shards bit-identical to direct sharding, release everything the naive
//! flow leaves behind, restore the update state exactly, keep pool
//! accounting balanced across alternating flows, and publish
//! generation-layout versions into the weight bus that round-trip
//! bit-identically with shard-level dedup.

use std::sync::Arc;

use mindspeed_rl::memory::MemoryPool;
use mindspeed_rl::parallel::{ModelWeights, ParallelLayout};
use mindspeed_rl::resharding::Resharder;
use mindspeed_rl::transfer_dock::NetworkModel;
use mindspeed_rl::util::rng::Rng;

const GIB: u64 = 1 << 30;

fn random_layout_pair(rng: &mut Rng, world: usize, moe: bool) -> Option<(ParallelLayout, ParallelLayout)> {
    let divisors: Vec<usize> = (1..=world).filter(|d| world % d == 0).collect();
    let mut pick = |rng: &mut Rng| divisors[rng.below(divisors.len())];
    for _ in 0..50 {
        let (utp, gtp) = (pick(rng), pick(rng));
        let (udp, gdp) = (world / utp, world / gtp);
        // EP 8 over 4 experts exercises the fractional (expert-TP)
        // placement; invalid combos for small worlds are retried away
        let uep = if moe { [1, 2, 4, 8][rng.below(4)] } else { 1 };
        let gep = if moe { [1, 2, 4, 8][rng.below(4)] } else { 1 };
        let u = ParallelLayout { tp: utp, pp: 1, dp: udp, ep: uep, cp: 1 };
        let g = ParallelLayout { tp: gtp, pp: 1, dp: gdp, ep: gep, cp: 1 };
        if u.validate().is_ok() && g.validate().is_ok() {
            return Some((u, g));
        }
    }
    None
}

#[test]
fn allgather_swap_bit_exact_for_random_layouts() {
    let mut rng = Rng::new(42);
    let mut tested = 0;
    for case in 0..25 {
        let world = [2usize, 4, 8][rng.below(3)];
        let moe = rng.below(2) == 1;
        let weights = if moe {
            ModelWeights::moe_like(2, 32, 64, 4).with_test_data(case)
        } else {
            ModelWeights::dense_like(3, 64, 128).with_test_data(case)
        };
        let Some((u, g)) = random_layout_pair(&mut rng, world, moe) else { continue };
        let mut rs = Resharder::new(weights, u, g, GIB, 64 * GIB, 8, NetworkModel::paper())
            .unwrap_or_else(|e| panic!("case {case} {u:?}->{g:?}: {e}"));
        rs.reshard_allgather_swap().unwrap();
        let n = rs.verify_gen_shards().unwrap();
        assert!(n > 0, "case {case} verified nothing");
        // every device's update block must be on the host now
        for d in 0..world {
            assert_eq!(
                rs.where_is_update_block(d),
                mindspeed_rl::resharding::ShardLocation::Host
            );
        }
        // swap back restores device residency and frees all host bytes
        rs.swap_back_h2d().unwrap();
        assert_eq!(rs.host_pools.iter().map(|p| p.live_bytes()).sum::<u64>(), 0);
        tested += 1;
    }
    assert!(tested >= 15, "too few valid random cases ({tested})");
}

#[test]
fn naive_bit_exact_and_never_less_redundant_than_swap() {
    let mut rng = Rng::new(7);
    for case in 0..15 {
        let world = [2usize, 4][rng.below(2)];
        let weights = ModelWeights::dense_like(2, 32, 64).with_test_data(100 + case);
        let Some((u, g)) = random_layout_pair(&mut rng, world, false) else { continue };
        let mut naive =
            Resharder::new(weights.clone(), u, g, GIB, 64 * GIB, 8, NetworkModel::paper())
                .unwrap();
        let rep_n = naive.reshard_naive().unwrap();
        naive.verify_gen_shards().unwrap();
        let mut swap =
            Resharder::new(weights, u, g, GIB, 64 * GIB, 8, NetworkModel::paper()).unwrap();
        let rep_s = swap.reshard_allgather_swap().unwrap();
        swap.verify_gen_shards().unwrap();
        assert_eq!(rep_s.redundant_bytes, 0);
        // swap never leaves less KV headroom than naive
        for (a, b) in swap.kv_headroom().iter().zip(naive.kv_headroom()) {
            assert!(*a >= b, "case {case}: swap headroom {a} < naive {b}");
        }
        let _ = rep_n;
    }
}

/// The resharding→bus integration property: for random valid layout
/// pairs, each reshard's generation layout published into the weight bus
/// reconstructs bit-identically to the live gen shards, pool-charged bus
/// bytes equal Σ unique shard bytes throughout, and when only one weight
/// trains between reshards the shard-level retention stays strictly
/// below the full-copy equivalent.
#[test]
fn reshard_bus_publish_round_trips_for_random_layouts() {
    let mut rng = Rng::new(99);
    let mut tested = 0;
    for case in 0..12 {
        let world = [2usize, 4][rng.below(2)];
        let weights = ModelWeights::dense_like(2, 32, 64).with_test_data(500 + case);
        let Some((u, g)) = random_layout_pair(&mut rng, world, false) else { continue };
        let mut rs =
            Resharder::new(weights, u, g, GIB, 64 * GIB, 8, NetworkModel::paper()).unwrap();
        rs.reshard_allgather_swap().unwrap();
        let pool = Arc::new(MemoryPool::unbounded("weightbus"));
        let bus = rs.seed_weight_bus(4, Some(Arc::clone(&pool))).unwrap();
        let names = rs.gen_slice_names().unwrap();
        for cycle in 0..3 {
            rs.swap_back_h2d().unwrap();
            // one weight "trains" between reshards
            rs.perturb_weight("l0.attn", 0.5).unwrap();
            let (rep, v) = rs.reshard_allgather_swap_into(&bus).unwrap();
            assert!(rep.bus_published_bytes > 0, "case {case} cycle {cycle}");
            rs.verify_gen_shards().unwrap();
            // the published version is the gen layout, slice for slice
            let view = bus.get(v).unwrap();
            assert_eq!(view.len(), names.len());
            for (i, (dev, name)) in names.iter().enumerate() {
                assert_eq!(
                    view.tensor(i).as_f32().unwrap(),
                    rs.gen_shard(*dev, name).unwrap().as_slice(),
                    "case {case} cycle {cycle}: slice ({dev}, {name}) mismatch"
                );
            }
            // pool accounting tracks unique retained shard bytes exactly
            assert_eq!(pool.live_bytes(), bus.retained_bytes(), "case {case} cycle {cycle}");
            // single-weight deltas dedup: strictly below full copies
            assert!(
                bus.retained_bytes() < bus.naive_equivalent_bytes(),
                "case {case} cycle {cycle}: {} !< {}",
                bus.retained_bytes(),
                bus.naive_equivalent_bytes()
            );
        }
        tested += 1;
    }
    assert!(tested >= 6, "too few valid random cases ({tested})");
}

/// The leak regression generalized over random layouts: alternating
/// naive / allgather–swap / swap-back cycles must return every device
/// pool to its construction baseline — the naive flow's gathered buffers
/// are freed eagerly at the start of the next reshard rather than parked
/// forever.
#[test]
fn alternating_flows_restore_baseline_for_random_layouts() {
    let mut rng = Rng::new(13);
    let mut tested = 0;
    for case in 0..8 {
        let world = [2usize, 4][rng.below(2)];
        let weights = ModelWeights::dense_like(2, 32, 64).with_test_data(900 + case);
        let Some((u, g)) = random_layout_pair(&mut rng, world, false) else { continue };
        let mut rs =
            Resharder::new(weights, u, g, GIB, 64 * GIB, 8, NetworkModel::paper()).unwrap();
        let baseline: Vec<u64> = rs.device_pools.iter().map(|p| p.live_bytes()).collect();
        for cycle in 0..2 {
            rs.reshard_naive().unwrap();
            rs.reshard_allgather_swap().unwrap();
            rs.swap_back_h2d().unwrap();
            let live: Vec<u64> = rs.device_pools.iter().map(|p| p.live_bytes()).collect();
            assert_eq!(live, baseline, "case {case} cycle {cycle}: baseline not restored");
            assert_eq!(
                rs.host_pools.iter().map(|p| p.live_bytes()).sum::<u64>(),
                0,
                "case {case} cycle {cycle}: host swap space leaked"
            );
        }
        tested += 1;
    }
    assert!(tested >= 4, "too few valid random cases ({tested})");
}

/// Asymmetric-EP property suite: for random MoE inventories and layout
/// pairs whose EP degree *changes* across the train→infer boundary
/// (including the fractional EP8-over-4-experts placement), the
/// allgather–swap reshard is bit-exact against direct sharding, a bus
/// publish after perturbing a random subset of expert weights retains
/// exactly the touched experts' slices, pool-charged bus bytes stay
/// balanced, and alternating naive/swap runs restore the device pools
/// to their construction baseline.
#[test]
fn asymmetric_ep_reshard_and_bus_retention_properties() {
    let mut rng = Rng::new(77);
    let mut tested = 0;
    for case in 0..20 {
        let num_experts = [2usize, 4][rng.below(2)];
        let weights =
            ModelWeights::moe_like(2, 32, 64, num_experts).with_test_data(700 + case);
        // draw until the EP degree differs across the boundary
        let Some((u, g)) = (0..50).find_map(|_| {
            let pair = random_layout_pair(&mut rng, 8, true)?;
            (pair.0.ep != pair.1.ep).then_some(pair)
        }) else {
            continue;
        };
        let mut rs =
            Resharder::new(weights, u, g, GIB, 64 * GIB, 8, NetworkModel::paper())
                .unwrap_or_else(|e| panic!("case {case} {u:?}->{g:?}: {e}"));
        let baseline: Vec<u64> = rs.device_pools.iter().map(|p| p.live_bytes()).collect();

        let rep = rs.reshard_allgather_swap().unwrap();
        assert!(rs.verify_gen_shards().unwrap() > 0, "case {case} verified nothing");
        assert_eq!(rep.redundant_bytes, 0, "case {case}");
        let pool = Arc::new(MemoryPool::unbounded("weightbus"));
        let bus = rs.seed_weight_bus(4, Some(Arc::clone(&pool))).unwrap();
        let names = rs.gen_slice_names().unwrap();
        rs.swap_back_h2d().unwrap();

        // perturb a random subset of expert weights — the "train step"
        let expert_names: Vec<String> = rs
            .weights
            .weights
            .iter()
            .filter(|w| matches!(w.kind, mindspeed_rl::parallel::WeightKind::Expert { .. }))
            .map(|w| w.name.clone())
            .collect();
        let mut touched: Vec<String> = Vec::new();
        for _ in 0..=rng.below(3) {
            let n = expert_names[rng.below(expert_names.len())].clone();
            if !touched.contains(&n) {
                rs.perturb_weight(&n, 0.25).unwrap();
                touched.push(n);
            }
        }
        let before = bus.retained_bytes();
        let (rep, v) = rs.reshard_allgather_swap_into(&bus).unwrap();
        rs.verify_gen_shards().unwrap();
        let grew = bus.retained_bytes() - before;
        let expect: u64 = names
            .iter()
            .enumerate()
            .filter(|(_, (_, n))| touched.contains(n))
            .map(|(i, _)| bus.get(v).unwrap().tensor(i).size_bytes() as u64)
            .sum();
        assert_eq!(
            grew, expect,
            "case {case} ({} -> {}): retention must grow by exactly the touched \
             experts' slices ({touched:?})",
            u.describe(),
            g.describe()
        );
        assert_eq!(rep.bus_published_bytes, grew, "case {case}: published delta mismatch");
        assert_eq!(pool.live_bytes(), bus.retained_bytes(), "case {case}: pool imbalance");

        // pool balance across alternating naive / swap flows
        rs.swap_back_h2d().unwrap();
        rs.reshard_naive().unwrap();
        rs.verify_gen_shards().unwrap();
        rs.reshard_allgather_swap().unwrap();
        rs.swap_back_h2d().unwrap();
        let live: Vec<u64> = rs.device_pools.iter().map(|p| p.live_bytes()).collect();
        assert_eq!(live, baseline, "case {case}: pools did not return to baseline");
        tested += 1;
    }
    assert!(tested >= 10, "too few valid asymmetric-EP cases ({tested})");
}

#[test]
fn group_advantage_properties() {
    // mean-zero per group, sign matches centered reward, for random inputs
    let mut rng = Rng::new(11);
    for _ in 0..50 {
        let gs = 2 + rng.below(7);
        let groups = 1 + rng.below(8);
        let rewards: Vec<f32> = (0..gs * groups).map(|_| rng.f32()).collect();
        let adv = mindspeed_rl::rewards::group_advantages(&rewards, gs);
        for (g, chunk) in adv.chunks(gs).enumerate() {
            let sum: f32 = chunk.iter().sum();
            assert!(sum.abs() < 1e-3, "group {g} advantage sum {sum}");
            let rmean: f32 = rewards[g * gs..(g + 1) * gs].iter().sum::<f32>() / gs as f32;
            for (a, r) in chunk.iter().zip(&rewards[g * gs..]) {
                if (r - rmean).abs() > 1e-4 {
                    assert_eq!(a.signum(), (r - rmean).signum());
                }
            }
        }
    }
}
