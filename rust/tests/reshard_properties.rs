//! Property tests (seeded-random, proptest-style) on the resharding flow:
//! for arbitrary valid layout pairs, allgather-swap must produce gen
//! shards bit-identical to direct sharding, release everything the naive
//! flow leaves behind, and restore the update state exactly.

use mindspeed_rl::parallel::{ModelWeights, ParallelLayout};
use mindspeed_rl::resharding::Resharder;
use mindspeed_rl::transfer_dock::NetworkModel;
use mindspeed_rl::util::rng::Rng;

const GIB: u64 = 1 << 30;

fn random_layout_pair(rng: &mut Rng, world: usize, moe: bool) -> Option<(ParallelLayout, ParallelLayout)> {
    let divisors: Vec<usize> = (1..=world).filter(|d| world % d == 0).collect();
    let mut pick = |rng: &mut Rng| divisors[rng.below(divisors.len())];
    for _ in 0..50 {
        let (utp, gtp) = (pick(rng), pick(rng));
        let (udp, gdp) = (world / utp, world / gtp);
        let uep = if moe { [1, 2, 4][rng.below(3)] } else { 1 };
        let gep = if moe { [1, 2, 4][rng.below(3)] } else { 1 };
        let u = ParallelLayout { tp: utp, pp: 1, dp: udp, ep: uep, cp: 1 };
        let g = ParallelLayout { tp: gtp, pp: 1, dp: gdp, ep: gep, cp: 1 };
        if u.validate().is_ok() && g.validate().is_ok() {
            return Some((u, g));
        }
    }
    None
}

#[test]
fn allgather_swap_bit_exact_for_random_layouts() {
    let mut rng = Rng::new(42);
    let mut tested = 0;
    for case in 0..25 {
        let world = [2usize, 4, 8][rng.below(3)];
        let moe = rng.below(2) == 1;
        let weights = if moe {
            ModelWeights::moe_like(2, 32, 64, 4).with_test_data(case)
        } else {
            ModelWeights::dense_like(3, 64, 128).with_test_data(case)
        };
        let Some((u, g)) = random_layout_pair(&mut rng, world, moe) else { continue };
        let mut rs = Resharder::new(weights, u, g, GIB, 64 * GIB, 8, NetworkModel::paper())
            .unwrap_or_else(|e| panic!("case {case} {u:?}->{g:?}: {e}"));
        rs.reshard_allgather_swap().unwrap();
        let n = rs.verify_gen_shards().unwrap();
        assert!(n > 0, "case {case} verified nothing");
        // every device's update block must be on the host now
        for d in 0..world {
            assert_eq!(
                rs.where_is_update_block(d),
                mindspeed_rl::resharding::ShardLocation::Host
            );
        }
        // swap back restores device residency and frees all host bytes
        rs.swap_back_h2d().unwrap();
        assert_eq!(rs.host_pools.iter().map(|p| p.live_bytes()).sum::<u64>(), 0);
        tested += 1;
    }
    assert!(tested >= 15, "too few valid random cases ({tested})");
}

#[test]
fn naive_bit_exact_and_never_less_redundant_than_swap() {
    let mut rng = Rng::new(7);
    for case in 0..15 {
        let world = [2usize, 4][rng.below(2)];
        let weights = ModelWeights::dense_like(2, 32, 64).with_test_data(100 + case);
        let Some((u, g)) = random_layout_pair(&mut rng, world, false) else { continue };
        let mut naive =
            Resharder::new(weights.clone(), u, g, GIB, 64 * GIB, 8, NetworkModel::paper())
                .unwrap();
        let rep_n = naive.reshard_naive().unwrap();
        naive.verify_gen_shards().unwrap();
        let mut swap =
            Resharder::new(weights, u, g, GIB, 64 * GIB, 8, NetworkModel::paper()).unwrap();
        let rep_s = swap.reshard_allgather_swap().unwrap();
        swap.verify_gen_shards().unwrap();
        assert_eq!(rep_s.redundant_bytes, 0);
        // swap never leaves less KV headroom than naive
        for (a, b) in swap.kv_headroom().iter().zip(naive.kv_headroom()) {
            assert!(*a >= b, "case {case}: swap headroom {a} < naive {b}");
        }
        let _ = rep_n;
    }
}

#[test]
fn group_advantage_properties() {
    // mean-zero per group, sign matches centered reward, for random inputs
    let mut rng = Rng::new(11);
    for _ in 0..50 {
        let gs = 2 + rng.below(7);
        let groups = 1 + rng.below(8);
        let rewards: Vec<f32> = (0..gs * groups).map(|_| rng.f32()).collect();
        let adv = mindspeed_rl::rewards::group_advantages(&rewards, gs);
        for (g, chunk) in adv.chunks(gs).enumerate() {
            let sum: f32 = chunk.iter().sum();
            assert!(sum.abs() < 1e-3, "group {g} advantage sum {sum}");
            let rmean: f32 = rewards[g * gs..(g + 1) * gs].iter().sum::<f32>() / gs as f32;
            for (a, r) in chunk.iter().zip(&rewards[g * gs..]) {
                if (r - rmean).abs() > 1e-4 {
                    assert_eq!(a.signum(), (r - rmean).signum());
                }
            }
        }
    }
}
