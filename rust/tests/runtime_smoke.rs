//! Integration test: load the tiny preset artifacts, execute each program.
use mindspeed_rl::runtime::{artifact_dir, Engine, Policy, TrainBatch, Tensor};

#[test]
fn tiny_preset_round_trip() {
    let engine = Engine::load(artifact_dir("tiny")).expect("run `make artifacts` first");
    let mut policy = Policy::load_initial(&engine, 1e-3).unwrap();
    let a = engine.manifest.artifact("logprobs").unwrap().clone();
    let (b, s) = (a.batch, a.seq);

    let tokens = Tensor::i32(&[b, s], vec![1; b * s]).unwrap();
    let lp = policy.logprobs(&engine, &tokens).unwrap();
    assert_eq!(lp.shape(), &[b, s - 1]);
    let lpv = lp.as_f32().unwrap();
    assert!(lpv.iter().all(|x| x.is_finite() && *x <= 0.0));

    let kv = policy.init_kv(&engine).unwrap();
    let pos = Tensor::i32(&[b], vec![0; b]).unwrap();
    let tok = Tensor::i32(&[b], vec![1; b]).unwrap();
    let (logits, kv2) = policy.decode_step(&engine, &kv, &pos, &tok).unwrap();
    assert_eq!(logits.shape(), &[b, engine.manifest.model.vocab_size]);
    assert_ne!(kv.as_f32().unwrap(), kv2.as_f32().unwrap());

    let batch = TrainBatch {
        tokens: Tensor::i32(&[b, s], vec![1; b * s]).unwrap(),
        resp_mask: Tensor::f32(&[b, s - 1], vec![1.0; b * (s - 1)]).unwrap(),
        old_lp: lp.clone(),
        ref_lp: lp.clone(),
        adv: Tensor::f32(&[b], vec![0.5; b]).unwrap(),
    };
    let before = policy.params[1].as_f32().unwrap().to_vec();
    let stats = policy.train_step(&engine, &batch).unwrap();
    assert!(stats.loss.is_finite());
    let after = policy.params[1].as_f32().unwrap();
    assert_ne!(before, after, "train_step must update weights");
}
