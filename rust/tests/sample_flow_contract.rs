//! SampleFlow contract tests, run against BOTH implementations — the
//! distributed transfer dock and the centralized replay-buffer baseline.
//! The pipelined executor treats the two interchangeably, so the
//! put / request / fetch / store / retire / release / wait_ready
//! invariants must hold identically for both, including under
//! multi-threaded producers and consumers.

use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use mindspeed_rl::runtime::Tensor;
use mindspeed_rl::transfer_dock::{
    DockTopology, FieldKind, ReplayBuffer, Sample, SampleFlow, Stage, TransferDock,
};
use mindspeed_rl::util::rng::Rng;

fn flows() -> Vec<(&'static str, Arc<dyn SampleFlow>)> {
    vec![
        ("transfer_dock", Arc::new(TransferDock::new(DockTopology::spread(4)))),
        ("replay_buffer", Arc::new(ReplayBuffer::new(0))),
    ]
}

fn prompts(n: usize) -> Vec<Sample> {
    (0..n)
        .map(|i| Sample::new_prompt(u64::MAX, i as u64 / 2, format!("{i}+1="), i as i64 + 1))
        .collect()
}

fn finish_generation(flow: &dyn SampleFlow, index: u64) {
    finish_generation_at(flow, index, 1);
}

fn finish_generation_at(flow: &dyn SampleFlow, index: u64, behavior_version: u64) {
    flow.store_generation(
        0,
        index,
        vec![
            (FieldKind::Tokens, Tensor::i32(&[8], vec![1; 8]).unwrap()),
            (FieldKind::RespMask, Tensor::zeros(&[7])),
        ],
        "42".into(),
        2,
        behavior_version,
    )
    .unwrap();
}

#[test]
fn lifecycle_and_readiness() {
    for (name, flow) in flows() {
        let idx = flow.put_samples(prompts(2)).unwrap();
        // fresh prompts: only generation is ready
        assert!(flow.request_ready(Stage::OldLogprob, 10).unwrap().is_empty(), "{name}");
        assert!(flow.request_ready(Stage::Update, 10).unwrap().is_empty(), "{name}");
        let gen = flow.request_ready(Stage::Generation, 10).unwrap();
        assert_eq!(gen.len(), 2, "{name}");

        finish_generation(flow.as_ref(), idx[0]);
        // generation writeback unlocks the three downstream-of-gen stages
        for stage in [Stage::OldLogprob, Stage::RefLogprob, Stage::Reward] {
            let ready = flow.request_ready(stage, 10).unwrap();
            assert_eq!(ready.len(), 1, "{name} {stage:?}");
            assert_eq!(ready[0].index, idx[0], "{name}");
            flow.release(stage, &[idx[0]]);
        }
        // update still gated on the remaining fields
        assert!(flow.request_ready(Stage::Update, 10).unwrap().is_empty(), "{name}");
        flow.store_fields(1, idx[0], vec![(FieldKind::OldLp, Tensor::zeros(&[7]))]).unwrap();
        flow.store_fields(2, idx[0], vec![(FieldKind::RefLp, Tensor::zeros(&[7]))]).unwrap();
        flow.store_fields(3, idx[0], vec![(FieldKind::Reward, Tensor::scalar_f32(1.0))])
            .unwrap();
        let upd = flow.request_ready(Stage::Update, 10).unwrap();
        assert_eq!(upd.len(), 1, "{name}");

        // fetch serves a copy with everything the update state needs
        let fetched = flow.fetch(3, &upd).unwrap();
        assert_eq!(fetched[0].completion_text, "42", "{name}");
        assert!(fetched[0].ready_for_update(), "{name}");

        // retire consumes; nothing is ready anywhere afterwards
        assert!(flow.retire(idx[0]).is_some(), "{name}");
        assert!(flow.retire(idx[0]).is_none(), "{name} double retire");
        for stage in Stage::ALL {
            assert!(
                flow.request_ready(stage, 10).unwrap().iter().all(|m| m.index != idx[0]),
                "{name} {stage:?} still sees retired sample"
            );
        }
        assert_eq!(flow.len(), 1, "{name} one unfinished sample remains");
    }
}

#[test]
fn no_double_dispatch_and_release() {
    for (name, flow) in flows() {
        flow.put_samples(prompts(4)).unwrap();
        let a = flow.request_ready(Stage::Generation, 2).unwrap();
        let b = flow.request_ready(Stage::Generation, 10).unwrap();
        assert_eq!(a.len(), 2, "{name}");
        assert_eq!(b.len(), 2, "{name}");
        let ai: Vec<u64> = a.iter().map(|m| m.index).collect();
        assert!(b.iter().all(|m| !ai.contains(&m.index)), "{name} double dispatch");
        // everything claimed: the pool is empty
        assert!(flow.request_ready(Stage::Generation, 10).unwrap().is_empty(), "{name}");
        // releasing puts the claimed work back, exactly once
        flow.release(Stage::Generation, &ai);
        let again = flow.request_ready(Stage::Generation, 10).unwrap();
        assert_eq!(again.len(), 2, "{name} release must restore claims");
        assert!(again.iter().all(|m| ai.contains(&m.index)), "{name}");
    }
}

/// The streaming scheduler's incremental claim: non-blocking, claims like
/// `request_ready`, and — the accounting contract — an *empty* poll moves
/// no ledger bytes. The scheduler polls between decode steps, so a
/// charged empty poll would make dispatch time a function of decode step
/// count instead of data movement.
#[test]
fn try_claim_charges_only_nonempty_polls() {
    for (name, flow) in flows() {
        let idx = flow.put_samples(prompts(2)).unwrap();
        let before = flow.ledger().total_bytes();
        let got = flow.try_claim(Stage::Generation, 10).unwrap();
        assert_eq!(got.len(), 2, "{name}");
        let after_hit = flow.ledger().total_bytes();
        assert!(after_hit > before, "{name}: a successful claim is a dispatch event");
        // claimed work is not re-dispatched, and the empty poll is free
        for _ in 0..50 {
            assert!(flow.try_claim(Stage::Generation, 10).unwrap().is_empty(), "{name}");
        }
        assert_eq!(
            flow.ledger().total_bytes(),
            after_hit,
            "{name}: empty try_claim polls must not move ledger bytes"
        );
        // and the claims behave like any other claim: release restores them
        flow.release(Stage::Generation, &idx);
        assert_eq!(flow.try_claim(Stage::Generation, 10).unwrap().len(), 2, "{name}");
    }
}

#[test]
fn wait_ready_returns_immediately_when_ready() {
    for (name, flow) in flows() {
        flow.put_samples(prompts(3)).unwrap();
        let t0 = Instant::now();
        let metas = flow
            .wait_ready(Stage::Generation, 2, Duration::from_secs(5))
            .unwrap();
        assert_eq!(metas.len(), 2, "{name} honors max_n");
        assert!(t0.elapsed() < Duration::from_secs(1), "{name} must not block");
    }
}

#[test]
fn wait_ready_times_out_empty() {
    for (name, flow) in flows() {
        flow.put_samples(prompts(1)).unwrap();
        // nothing is update-ready; the wait must expire empty, promptly
        let t0 = Instant::now();
        let metas = flow
            .wait_ready(Stage::Update, 10, Duration::from_millis(30))
            .unwrap();
        assert!(metas.is_empty(), "{name}");
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(25), "{name} returned too early");
        assert!(waited < Duration::from_secs(2), "{name} overslept");
    }
}

#[test]
fn wait_ready_wakes_on_concurrent_store() {
    for (name, flow) in flows() {
        let idx = flow.put_samples(prompts(1)).unwrap();
        // claim generation so the only path to OldLogprob readiness is the
        // store_generation below
        let gen = flow.request_ready(Stage::Generation, 1).unwrap();
        assert_eq!(gen.len(), 1, "{name}");

        let waiter = Arc::clone(&flow);
        let h = std::thread::spawn(move || {
            waiter.wait_ready(Stage::OldLogprob, 4, Duration::from_secs(10)).unwrap()
        });
        std::thread::sleep(Duration::from_millis(20));
        finish_generation(flow.as_ref(), idx[0]);
        let metas = h.join().unwrap();
        assert_eq!(metas.len(), 1, "{name} waiter must observe the writeback");
        assert_eq!(metas[0].index, idx[0], "{name}");
    }
}

/// Three stage threads race writebacks of *different fields to the same
/// samples* — the interleaving that once let an out-of-order metadata
/// broadcast un-ready a completed sample forever. Every sample must end
/// up update-ready exactly once.
#[test]
fn concurrent_multi_field_writebacks_reach_update() {
    const N: usize = 32;
    for (name, flow) in flows() {
        let idx = flow.put_samples(prompts(N)).unwrap();
        for &i in &idx {
            finish_generation(flow.as_ref(), i);
        }
        std::thread::scope(|scope| {
            for field in [FieldKind::OldLp, FieldKind::RefLp, FieldKind::Reward] {
                let flow = Arc::clone(&flow);
                let idx = idx.clone();
                scope.spawn(move || {
                    for &i in &idx {
                        let t = if field == FieldKind::Reward {
                            Tensor::scalar_f32(1.0)
                        } else {
                            Tensor::zeros(&[7])
                        };
                        flow.store_fields(1, i, vec![(field, t)]).unwrap();
                    }
                });
            }
        });
        let ready = flow.request_ready(Stage::Update, usize::MAX).unwrap();
        assert_eq!(ready.len(), N, "{name}: every sample must reach the update state");
        let again = flow.request_ready(Stage::Update, usize::MAX).unwrap();
        assert!(again.is_empty(), "{name}: update work dispatched twice");
    }
}

/// The behavior-policy version stamped by the generation writeback must
/// survive every later mutation of the sample's metadata: controller
/// claim latches, cross-stage writebacks (and the dock's metadata
/// re-broadcasts they trigger), fetches, and the final retire.
#[test]
fn version_stamp_survives_cross_stage_writebacks() {
    const STAMP: u64 = 7;
    for (name, flow) in flows() {
        let idx = flow.put_samples(prompts(1)).unwrap()[0];
        // fresh prompts are unstamped
        let gen = flow.request_ready(Stage::Generation, 1).unwrap();
        assert_eq!(gen[0].behavior_version, 0, "{name} prompt must be unstamped");
        finish_generation_at(flow.as_ref(), idx, STAMP);

        // the generation broadcast delivers the stamp to every stage
        let old = flow.request_ready(Stage::OldLogprob, 1).unwrap();
        assert_eq!(old[0].behavior_version, STAMP, "{name} old-lp meta lost the stamp");
        // claim is latched; now land a *cross-stage* writeback (reward)
        // while the old-lp claim is outstanding — the re-broadcast must
        // neither re-dispatch the claim nor alter the stamp
        flow.store_fields(2, idx, vec![(FieldKind::Reward, Tensor::scalar_f32(1.0))])
            .unwrap();
        assert!(
            flow.request_ready(Stage::OldLogprob, 1).unwrap().is_empty(),
            "{name} cross-stage writeback re-dispatched a latched claim"
        );
        let refl = flow.request_ready(Stage::RefLogprob, 1).unwrap();
        assert_eq!(
            refl[0].behavior_version, STAMP,
            "{name} re-broadcast after the reward writeback lost the stamp"
        );

        // payload fetches carry it too
        let fetched = flow.fetch(3, &refl).unwrap();
        assert_eq!(fetched[0].behavior_version, STAMP, "{name} fetched payload lost the stamp");

        // complete the remaining fields through the *other* stages; the
        // update-ready meta and the retired sample still carry the stamp
        flow.store_fields(1, idx, vec![(FieldKind::OldLp, Tensor::zeros(&[7]))]).unwrap();
        flow.store_fields(2, idx, vec![(FieldKind::RefLp, Tensor::zeros(&[7]))]).unwrap();
        let upd = flow.request_ready(Stage::Update, 1).unwrap();
        assert_eq!(upd.len(), 1, "{name}");
        assert_eq!(upd[0].behavior_version, STAMP, "{name} update meta lost the stamp");
        let retired = flow.retire(idx).unwrap();
        assert_eq!(retired.behavior_version, STAMP, "{name} retired sample lost the stamp");
    }
}

/// Stamps are per-sample, not global: samples generated under different
/// weight versions coexist in the flow and each claim reports its own.
#[test]
fn distinct_stamps_coexist_per_sample() {
    for (name, flow) in flows() {
        let idx = flow.put_samples(prompts(4)).unwrap();
        for (k, &i) in idx.iter().enumerate() {
            finish_generation_at(flow.as_ref(), i, 10 + k as u64);
        }
        let metas = flow.request_ready(Stage::Reward, 10).unwrap();
        assert_eq!(metas.len(), 4, "{name}");
        for m in &metas {
            let pos = idx.iter().position(|&i| i == m.index).unwrap();
            assert_eq!(m.behavior_version, 10 + pos as u64, "{name} sample {}", m.index);
        }
    }
}

/// N producer threads admit + finish generation; M consumer threads pull
/// OldLogprob work through `wait_ready` and write the field back. Every
/// sample must be consumed exactly once — the in-flight latch must hold
/// under contention, and no sample may be lost.
#[test]
fn multithreaded_producers_consumers() {
    const PRODUCERS: usize = 3;
    const PER_PRODUCER: usize = 20;
    const CONSUMERS: usize = 4;
    const TOTAL: usize = PRODUCERS * PER_PRODUCER;

    for (name, flow) in flows() {
        let processed = Arc::new(AtomicUsize::new(0));
        let seen: Arc<Mutex<HashSet<u64>>> = Arc::new(Mutex::new(HashSet::new()));

        std::thread::scope(|scope| {
            for _ in 0..PRODUCERS {
                let flow = Arc::clone(&flow);
                scope.spawn(move || {
                    for chunk in 0..PER_PRODUCER / 4 {
                        let idx = flow.put_samples(prompts(4)).unwrap();
                        for &i in &idx {
                            finish_generation(flow.as_ref(), i);
                        }
                        // stagger admissions a little
                        if chunk % 2 == 0 {
                            std::thread::yield_now();
                        }
                    }
                });
            }
            for _ in 0..CONSUMERS {
                let flow = Arc::clone(&flow);
                let processed = Arc::clone(&processed);
                let seen = Arc::clone(&seen);
                scope.spawn(move || {
                    let deadline = Instant::now() + Duration::from_secs(30);
                    while processed.load(Ordering::Relaxed) < TOTAL {
                        assert!(Instant::now() < deadline, "stress test wedged");
                        let metas = flow
                            .wait_ready(Stage::OldLogprob, 8, Duration::from_millis(20))
                            .unwrap();
                        if metas.is_empty() {
                            continue;
                        }
                        let samples = flow.fetch(1, &metas).unwrap();
                        for s in &samples {
                            flow.store_fields(
                                1,
                                s.index,
                                vec![(FieldKind::OldLp, Tensor::zeros(&[7]))],
                            )
                            .unwrap();
                            let fresh = seen.lock().unwrap().insert(s.index);
                            assert!(fresh, "sample {} dispatched twice", s.index);
                            processed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
            }
        });

        assert_eq!(
            processed.load(Ordering::Relaxed),
            TOTAL,
            "{name}: every sample consumed exactly once"
        );
        assert_eq!(seen.lock().unwrap().len(), TOTAL, "{name}");
        // all samples now carry OldLp; none is OldLogprob-ready anymore
        assert!(
            flow.request_ready(Stage::OldLogprob, TOTAL).unwrap().is_empty(),
            "{name}"
        );
    }
}

/// Elastic replicas pull through registered puller counts: a greedy
/// request takes only its fair share (⌈ready/P⌉), successive requests
/// drain the rest, and nothing is dispatched twice or lost — identical
/// semantics on the dock and the centralized buffer.
#[test]
fn fair_share_claims_across_registered_pullers() {
    for (name, flow) in flows() {
        let idx = flow.put_samples(prompts(8)).unwrap();
        flow.note_pullers(Stage::Generation, 2);
        assert_eq!(flow.ready_depth(Stage::Generation), 8, "{name}");
        let a = flow.request_ready(Stage::Generation, usize::MAX).unwrap();
        assert_eq!(a.len(), 4, "{name}: greedy claim must be fair-share capped");
        assert_eq!(flow.ready_depth(Stage::Generation), 4, "{name}");
        // peers drain the remainder; every sample dispatched exactly once
        let mut seen: HashSet<u64> = a.iter().map(|m| m.index).collect();
        loop {
            let more = flow.request_ready(Stage::Generation, usize::MAX).unwrap();
            if more.is_empty() {
                break;
            }
            for m in &more {
                assert!(seen.insert(m.index), "{name}: double dispatch of {}", m.index);
            }
        }
        assert_eq!(seen.len(), idx.len(), "{name}: every sample claimed exactly once");
        assert_eq!(flow.ready_depth(Stage::Generation), 0, "{name}");
        // deregistering restores the greedy handout
        flow.release(Stage::Generation, &idx);
        flow.note_pullers(Stage::Generation, 1);
        assert_eq!(
            flow.request_ready(Stage::Generation, usize::MAX).unwrap().len(),
            8,
            "{name}: single puller takes the whole queue again"
        );
    }
}

/// N concurrent replica threads per stage racing `wait_ready` on the
/// same controller: no double dispatch, no lost samples, and the claim
/// distribution is fair enough that every replica gets work (the
/// fair-share cap keeps one fast thread from monopolizing the queue).
#[test]
fn concurrent_stage_replicas_share_the_queue() {
    const REPLICAS: usize = 4;
    const TOTAL: usize = 64;
    for (name, flow) in flows() {
        let idx = flow.put_samples(prompts(TOTAL)).unwrap();
        for &i in &idx {
            finish_generation(flow.as_ref(), i);
        }
        flow.note_pullers(Stage::OldLogprob, REPLICAS);
        let processed = Arc::new(AtomicUsize::new(0));
        let seen: Arc<Mutex<HashSet<u64>>> = Arc::new(Mutex::new(HashSet::new()));
        let mut per_replica = Vec::new();
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for _ in 0..REPLICAS {
                let flow = Arc::clone(&flow);
                let processed = Arc::clone(&processed);
                let seen = Arc::clone(&seen);
                handles.push(scope.spawn(move || {
                    let mut max_gulp = 0usize;
                    let deadline = Instant::now() + Duration::from_secs(30);
                    while processed.load(Ordering::Relaxed) < TOTAL {
                        assert!(Instant::now() < deadline, "replica race wedged");
                        let metas = flow
                            .wait_ready(Stage::OldLogprob, usize::MAX, Duration::from_millis(10))
                            .unwrap();
                        max_gulp = max_gulp.max(metas.len());
                        for m in &metas {
                            assert!(
                                seen.lock().unwrap().insert(m.index),
                                "sample {} dispatched to two replicas",
                                m.index
                            );
                            flow.store_fields(
                                1,
                                m.index,
                                vec![(FieldKind::OldLp, Tensor::zeros(&[7]))],
                            )
                            .unwrap();
                            processed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    max_gulp
                }));
            }
            for h in handles {
                per_replica.push(h.join().unwrap());
            }
        });
        assert_eq!(processed.load(Ordering::Relaxed), TOTAL, "{name}: no sample lost");
        assert_eq!(seen.lock().unwrap().len(), TOTAL, "{name}");
        // fair-share cap: with 64 samples over 4 registered pullers no
        // single claim may exceed ⌈64/4⌉ = 16 — a replica claiming the
        // whole queue in one gulp (the pre-fairness failure mode) is
        // impossible by construction, every gulp leaves work for peers
        assert!(
            per_replica.iter().all(|&g| g <= TOTAL / REPLICAS),
            "{name}: a single claim exceeded the fair share: {per_replica:?}"
        );
        assert!(
            flow.request_ready(Stage::OldLogprob, usize::MAX).unwrap().is_empty(),
            "{name}"
        );
    }
}

/// Lease-lifecycle contract, identical across both flows: claims never
/// expire while the clock stands still, expire exactly at the configured
/// tick, come back requestable with bumped attempt counters, and the
/// recovery accounting stays self-consistent.
#[test]
fn abandoned_claims_reclaim_identically_on_both_flows() {
    let flows: Vec<(&'static str, Arc<dyn SampleFlow>)> = vec![
        ("transfer_dock", Arc::new(TransferDock::with_lease(DockTopology::spread(4), 3))),
        ("replay_buffer", Arc::new(ReplayBuffer::with_lease(0, 3))),
    ];
    for (name, flow) in flows {
        flow.put_samples(prompts(6)).unwrap();
        // a worker claims everything, then "dies" (no writeback, no
        // release, the claim simply goes silent)
        let claimed = flow.request_ready(Stage::Generation, 10).unwrap();
        assert_eq!(claimed.len(), 6, "{name}");
        assert!(flow.request_ready(Stage::Generation, 10).unwrap().is_empty(), "{name}");
        // the clock alone decides recovery: 2 ticks < lease of 3 → held
        assert_eq!(flow.tick_lease_clock(), 0, "{name}");
        assert_eq!(flow.tick_lease_clock(), 0, "{name}");
        assert!(flow.request_ready(Stage::Generation, 10).unwrap().is_empty(), "{name}");
        // third tick: every claim expires at once
        assert_eq!(flow.tick_lease_clock(), 6, "{name}");
        let again = flow.request_ready(Stage::Generation, 10).unwrap();
        assert_eq!(again.len(), 6, "{name}: reclaimed samples must redispatch");
        let s = flow.lease_stats();
        assert_eq!(s.reclaimed, 6, "{name}");
        assert_eq!(s.redispatched, 6, "{name}");
        assert_eq!(s.attempt_bumps, 6, "{name}");
        assert_eq!(s.max_attempt, 1, "{name}");
        assert!(s.consistent(), "{name}: {s:?}");
    }
}

/// Satellite: randomized interleavings of `release`, `store_fields`, and
/// `retire` across stage threads with fixed seeds. Invariants: no double
/// dispatch while leases are live (the latch holds under contention), no
/// double retire, and no permanently-stranded sample — after the dust
/// settles plus a lease worth of ticks, every surviving sample is either
/// done or claimable again.
#[test]
fn release_store_retire_interleavings_leave_nothing_stranded() {
    const N: usize = 24;
    const THREADS: usize = 3;
    let flows: Vec<(&'static str, Arc<dyn SampleFlow>)> = vec![
        ("transfer_dock", Arc::new(TransferDock::with_lease(DockTopology::spread(4), 64))),
        ("replay_buffer", Arc::new(ReplayBuffer::with_lease(0, 64))),
    ];
    for (name, flow) in flows {
        let idx = flow.put_samples(prompts(N)).unwrap();
        for &i in &idx {
            finish_generation(flow.as_ref(), i);
        }
        // sample → currently-claimed-by-a-thread latch mirror; used to
        // prove the flow never hands one sample to two threads at once
        let active: Arc<Mutex<HashSet<u64>>> = Arc::new(Mutex::new(HashSet::new()));
        let retired: Arc<Mutex<HashSet<u64>>> = Arc::new(Mutex::new(HashSet::new()));
        let settled = Arc::new(AtomicUsize::new(0)); // OldLp stored or retired

        std::thread::scope(|scope| {
            for t in 0..THREADS {
                let flow = Arc::clone(&flow);
                let active = Arc::clone(&active);
                let retired = Arc::clone(&retired);
                let settled = Arc::clone(&settled);
                scope.spawn(move || {
                    let mut rng = Rng::new(0x5eed ^ t as u64);
                    let deadline = Instant::now() + Duration::from_secs(30);
                    while settled.load(Ordering::Relaxed) < N {
                        assert!(Instant::now() < deadline, "interleaving test wedged");
                        let metas = flow
                            .wait_ready(Stage::OldLogprob, 4, Duration::from_millis(5))
                            .unwrap();
                        for m in &metas {
                            assert!(
                                active.lock().unwrap().insert(m.index),
                                "sample {} dispatched to two threads at once",
                                m.index
                            );
                        }
                        for m in &metas {
                            match rng.below(10) {
                                // 50%: do the work
                                0..=4 => {
                                    flow.store_fields(
                                        1,
                                        m.index,
                                        vec![(FieldKind::OldLp, Tensor::zeros(&[7]))],
                                    )
                                    .unwrap();
                                    active.lock().unwrap().remove(&m.index);
                                    settled.fetch_add(1, Ordering::Relaxed);
                                }
                                // 30%: hand the claim back
                                5..=7 => {
                                    active.lock().unwrap().remove(&m.index);
                                    flow.release(Stage::OldLogprob, &[m.index]);
                                }
                                // 20%: consume the sample outright
                                _ => {
                                    active.lock().unwrap().remove(&m.index);
                                    let s = flow.retire(m.index);
                                    assert!(s.is_some(), "sample {} retired twice", m.index);
                                    assert!(
                                        retired.lock().unwrap().insert(m.index),
                                        "retired set saw {} twice",
                                        m.index
                                    );
                                    settled.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        }
                    }
                });
            }
        });

        // nothing stranded: after a full lease of ticks, whatever is
        // still resident must be either past OldLogprob or claimable
        for _ in 0..65 {
            flow.tick_lease_clock();
        }
        let leftover = flow.request_ready(Stage::OldLogprob, usize::MAX).unwrap();
        assert!(
            leftover.is_empty(),
            "{name}: {} samples still claim OldLogprob work after settling",
            leftover.len()
        );
        let n_retired = retired.lock().unwrap().len();
        assert_eq!(flow.len(), N - n_retired, "{name}: resident count must match retires");
        let s = flow.lease_stats();
        assert!(s.consistent(), "{name}: {s:?}");
    }
}

// --------------------------------------------------------------------
// Sharded dock (--dock-shards K): the SampleFlow contract must hold
// when each stage's controller is partitioned into K shards with
// work stealing between them (tests/sharded_dock.rs pins the full
// differential oracle; these pin the contract-level invariants).

fn sharded(shards: usize, lease_ticks: u64, steal_threshold: usize) -> Arc<TransferDock> {
    Arc::new(TransferDock::with_shards(
        DockTopology::spread(4),
        lease_ticks,
        shards,
        steal_threshold,
    ))
}

/// Samples hash across K shards, claims round-robin across home shards,
/// and drained shards steal — yet no sample may ever be dispatched to
/// two claimants, and none may be lost.
#[test]
fn sharded_dock_no_double_dispatch_across_shards() {
    const N: usize = 32;
    for k in [2usize, 3, 4] {
        let flow = sharded(k, 64, 0);
        let idx = flow.put_samples(prompts(N)).unwrap();
        let mut seen: HashSet<u64> = HashSet::new();
        loop {
            // small batches force the claim cursor over every shard and
            // the tail through the steal path
            let metas = flow.request_ready(Stage::Generation, 5).unwrap();
            if metas.is_empty() {
                break;
            }
            for m in &metas {
                assert!(seen.insert(m.index), "K={k}: double dispatch of {}", m.index);
            }
        }
        assert_eq!(seen.len(), idx.len(), "K={k}: every sample claimed exactly once");
        assert_eq!(flow.ready_depth(Stage::Generation), 0, "K={k}");
    }
}

/// A stolen claim lives under the victim shard's lease table: it expires
/// on the same clock, redispatches claimably, and the merged recovery
/// accounting stays self-consistent — stealing must not create a second
/// lease authority.
#[test]
fn steal_preserves_lease_invariants() {
    let flow = sharded(2, 3, 0);
    flow.put_samples(prompts(6)).unwrap();
    // one greedy claim drains the home shard and steals the sibling dry;
    // then the claimant goes silent
    let claimed = flow.request_ready(Stage::Generation, usize::MAX).unwrap();
    assert_eq!(claimed.len(), 6, "steal must fill the greedy claim");
    assert!(flow.request_ready(Stage::Generation, usize::MAX).unwrap().is_empty());
    // held until exactly the lease tick, across both shards at once
    assert_eq!(flow.tick_lease_clock(), 0);
    assert_eq!(flow.tick_lease_clock(), 0);
    assert_eq!(flow.tick_lease_clock(), 6, "stolen claims expire with the rest");
    let again = flow.request_ready(Stage::Generation, usize::MAX).unwrap();
    assert_eq!(again.len(), 6, "reclaimed stolen claims must redispatch");
    let s = flow.lease_stats();
    assert_eq!(s.reclaimed, 6);
    assert_eq!(s.redispatched, 6);
    assert!(s.consistent(), "{s:?}");
}

/// Eq. 4 accounting for steals: a cross-shard steal is one extra
/// InterNode RPC per victim shard that hands work over — not per sample,
/// and never for empty victims.
#[test]
fn cross_shard_steal_charges_exactly_one_internode_rpc() {
    let flow = sharded(2, 64, 0);
    flow.put_samples(prompts(8)).unwrap();
    let before = flow.ledger();
    // the greedy claim drains the home shard, then steals the single
    // sibling's whole pool in one handout
    let metas = flow.request_ready(Stage::Generation, usize::MAX).unwrap();
    assert_eq!(metas.len(), 8);
    let after = flow.ledger();
    assert_eq!(
        after.requests - before.requests,
        1,
        "one cross-shard steal must cost exactly one InterNode RPC"
    );
    assert_eq!(
        after.local_requests - before.local_requests,
        1,
        "the home-shard claim itself stays a local round-trip"
    );
    // a second greedy claim finds both shards empty: no steal, no RPC
    let before = flow.ledger();
    assert!(flow.request_ready(Stage::Generation, usize::MAX).unwrap().is_empty());
    let after = flow.ledger();
    assert_eq!(after.requests, before.requests, "empty steals are free");
}

/// The fair-share claim cap is per shard: with P registered pullers
/// spread over K shards, a greedy claim takes at most its home shard's
/// fair share (plus nothing — a non-drained home never steals), so one
/// fast replica cannot monopolize the queue.
#[test]
fn per_shard_fair_share_cap_holds() {
    const N: usize = 16;
    let flow = sharded(2, 64, 0);
    let idx = flow.put_samples(prompts(N)).unwrap();
    flow.note_pullers(Stage::Generation, 4); // 2 pullers per shard
    let a = flow.request_ready(Stage::Generation, usize::MAX).unwrap();
    assert!(!a.is_empty());
    assert!(
        a.len() <= N / 2,
        "greedy claim must be capped at the home shard's fair share, got {}",
        a.len()
    );
    // peers drain the rest; exactly-once dispatch holds throughout
    let mut seen: HashSet<u64> = a.iter().map(|m| m.index).collect();
    loop {
        let more = flow.request_ready(Stage::Generation, usize::MAX).unwrap();
        if more.is_empty() {
            break;
        }
        for m in &more {
            assert!(seen.insert(m.index), "double dispatch of {}", m.index);
        }
    }
    assert_eq!(seen.len(), idx.len(), "every sample claimed exactly once");
}

// ------------------------------------------------------------ tenancy

/// `n` samples striped round-robin over `tenants` tenant jobs by group
/// (two samples per group, like the GRPO workload).
fn tenant_prompts(n: usize, tenants: u32) -> Vec<Sample> {
    (0..n)
        .map(|i| {
            let group = i as u64 / 2;
            Sample::new_prompt(u64::MAX, group, format!("{i}+1="), i as i64 + 1)
                .with_tenant((group % tenants as u64) as u32)
        })
        .collect()
}

/// Weighted-fair handout: with both tenants backlogged at weights 3:1,
/// 16 single-sample claims split 12/4 — deficit-weighted round robin
/// tracks the ratio within one claim batch of slack. Holds identically
/// for both flow implementations.
#[test]
fn weighted_tenants_split_claims_three_to_one() {
    for (name, flow) in flows() {
        flow.set_tenant_weights(&[(0, 3), (1, 1)]);
        flow.put_samples(tenant_prompts(32, 2)).unwrap();
        let mut counts = (0i64, 0i64);
        for _ in 0..16 {
            for m in flow.request_ready(Stage::Generation, 1).unwrap() {
                match m.tenant {
                    0 => counts.0 += 1,
                    _ => counts.1 += 1,
                }
            }
        }
        assert_eq!(counts.0 + counts.1, 16, "{name}: backlogged pool must fill every claim");
        assert!(
            (counts.0 - 12).abs() <= 2,
            "{name}: 3:1 weights must hand out ~12/4, got {}/{}",
            counts.0,
            counts.1
        );
        // the ledger the reports read agrees with what we observed
        let claims = flow.tenant_claims();
        let served = |t: u32| claims.iter().find(|(id, _)| *id == t).map_or(0, |(_, c)| *c);
        assert_eq!(served(0), counts.0 as u64, "{name}");
        assert_eq!(served(1), counts.1 as u64, "{name}");
    }
}

/// Work conservation: a tenant with zero backlog donates its share — the
/// backlogged tenant takes the whole pool instead of idling behind a
/// reservation, and arbitration resumes the moment the idle tenant's
/// work arrives.
#[test]
fn zero_backlog_tenant_donates_its_share() {
    for (name, flow) in flows() {
        flow.set_tenant_weights(&[(0, 3), (1, 1)]);
        let all = tenant_prompts(32, 2);
        // only tenant 1 has work: its claims must not be throttled to a
        // 1-in-4 share by the absent heavyweight
        let t1_first: Vec<Sample> =
            all.iter().filter(|s| s.tenant == 1).take(4).cloned().collect();
        flow.put_samples(t1_first).unwrap();
        let metas = flow.request_ready(Stage::Generation, 4).unwrap();
        assert_eq!(metas.len(), 4, "{name}: the idle tenant's share must be donated");
        assert!(metas.iter().all(|m| m.tenant == 1), "{name}");
        // the heavyweight's backlog arrives (alongside more tenant-1
        // work): the donation was a deficit, not a forfeit — tenant 0
        // catches up before tenant 1 is served again
        let rest: Vec<Sample> = all
            .into_iter()
            .filter(|s| s.tenant == 0 || s.group >= 8)
            .collect();
        flow.put_samples(rest).unwrap();
        for i in 0..4 {
            let m = flow.request_ready(Stage::Generation, 1).unwrap();
            assert_eq!(m.len(), 1, "{name}");
            assert_eq!(
                m[0].tenant, 0,
                "{name}: claim {i} after the donation must repay tenant 0's deficit"
            );
        }
    }
}

/// Quota exhaustion is per-tenant: the capped tenant's admissions defer
/// (strict `try_charge` refuses, nothing is charged), while the other
/// tenant's admission and `try_claim` path is completely unaffected;
/// uncharging at retire re-opens the capped tenant.
#[test]
fn quota_exhaustion_defers_only_the_capped_tenant() {
    use mindspeed_rl::memory::TenantQuotas;
    const BYTES: u64 = 512;
    for (name, flow) in flows() {
        let quotas = TenantQuotas::new();
        quotas.set_quota(0, Some(2 * BYTES)); // tenant 0: two samples resident
        let mut deferred: Vec<Sample> = Vec::new();
        for s in tenant_prompts(16, 2) {
            // the driver's admission gate: strict charge, defer on refusal
            if quotas.try_charge(s.tenant, BYTES) {
                flow.put_samples(vec![s]).unwrap();
            } else {
                deferred.push(s);
            }
        }
        // tenant 0 capped at 2; tenant 1 (uncapped) fully admitted
        assert_eq!(deferred.len(), 6, "{name}: exactly tenant 0's overflow defers");
        assert!(deferred.iter().all(|s| s.tenant == 0), "{name}");
        let metas = flow.try_claim(Stage::Generation, usize::MAX).unwrap();
        assert_eq!(metas.len(), 10, "{name}: sibling admission must be unaffected");
        assert_eq!(metas.iter().filter(|m| m.tenant == 1).count(), 8, "{name}");
        assert_eq!(metas.iter().filter(|m| m.tenant == 0).count(), 2, "{name}");
        // two tenant-0 retires uncharge; the freed quota re-admits
        // exactly two deferred samples
        quotas.uncharge(0, BYTES);
        quotas.uncharge(0, BYTES);
        let mut readmitted = 0;
        deferred.retain(|s| {
            if quotas.try_charge(s.tenant, BYTES) {
                flow.put_samples(vec![s.clone()]).unwrap();
                readmitted += 1;
                false
            } else {
                true
            }
        });
        assert_eq!(readmitted, 2, "{name}: freed quota re-opens the tenant");
        assert_eq!(deferred.len(), 4, "{name}");
        let more = flow.try_claim(Stage::Generation, usize::MAX).unwrap();
        assert_eq!(more.len(), 2, "{name}");
        assert!(more.iter().all(|m| m.tenant == 0), "{name}");
        let snap = quotas.snapshot();
        let t0 = &snap.iter().find(|(t, _)| *t == 0).unwrap().1;
        assert_eq!(t0.deferrals, 6 + 4, "{name}: every refusal counts a deferral");
        assert_eq!(t0.high_water, 2 * BYTES, "{name}");
    }
}
