//! Sharded-dock differential oracle (`--dock-shards K`,
//! `--steal-threshold D`).
//!
//! The tentpole invariant: for ANY shard count K and any steal
//! schedule, a run retires the *identical* sample map — same indices,
//! same groups, same behavior-version stamps — as the K=1
//! single-controller dock over the same seeded workload. Sharding and
//! stealing are dispatch-topology choices; they must never change what
//! gets trained. The oracle is composed with every other dataflow
//! feature: chaos kills/stalls, elastic stage replicas, autoscaling,
//! streaming generation, and resumable partial rollouts.
//!
//! Fixed seeds by default; `CHAOS_RANDOM_SEEDS=1` (the scheduled CI
//! job) appends time-derived seeds for a fuzzing pass, printing a
//! `[sharded-dock]` marker line the workflow greps for.

use mindspeed_rl::sim::chaos::{run_baseline, run_chaos, ChaosConfig, ChaosOutcome};
use mindspeed_rl::trainers::autoscale::AutoscaleConfig;
use mindspeed_rl::trainers::faults::FaultPlan;

fn base_cfg(seed: u64) -> ChaosConfig {
    ChaosConfig { iterations: 4, prompts_per_iter: 4, group_size: 2, seed, ..Default::default() }
}

fn with_shards(cfg: &ChaosConfig, k: usize, steal: usize) -> ChaosConfig {
    ChaosConfig { dock_shards: k, steal_threshold: steal, ..cfg.clone() }
}

fn seeds() -> Vec<u64> {
    let mut seeds = vec![5, 42];
    if std::env::var("CHAOS_RANDOM_SEEDS").as_deref() == Ok("1") {
        let t = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos() as u64;
        for i in 0..2u64 {
            seeds.push(t ^ (i + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        }
        eprintln!("[sharded-dock] randomized-seed mode: {seeds:?}");
    }
    seeds
}

/// The oracle proper: retired map identity (set AND stamps) against the
/// K=1 reference, plus the standing chaos invariants (zero loss, byte
/// conservation per warehouse, self-consistent recovery accounting).
fn assert_oracle(name: &str, cfg: &ChaosConfig, out: &ChaosOutcome, reference: &ChaosOutcome) {
    assert!(
        out.lossless(cfg),
        "{name}: loss — retired {}/{} resident {} recovery {:?}",
        out.retired.len(),
        cfg.total_samples(),
        out.resident_after,
        out.recovery
    );
    assert_eq!(
        out.retired, reference.retired,
        "{name}: retired map (set or stamps) diverged from the K=1 dock"
    );
    for (i, c) in out.conservation.iter().enumerate() {
        assert!(c.holds(), "{name}: warehouse {i} violates byte conservation: {c:?}");
    }
    let r = &out.recovery;
    assert!(r.consistent(), "{name}: recovery accounting inconsistent: {r:?}");
    assert_eq!(r.reclaimed, r.attempt_bumps, "{name}: {r:?}");
}

// --------------------------------------------------- fault-free sweep

/// Any K and any steal threshold, fault-free: bit-identical retired
/// maps to the K=1 dock AND to the centralized replay-buffer baseline,
/// with zero reclaims (sharding must not manufacture lease churn).
#[test]
fn any_shard_and_steal_schedule_matches_the_unsharded_dock() {
    for seed in seeds() {
        // generous lease: a fault-free run must not reclaim even if the
        // CI scheduler deschedules a worker briefly
        let cfg = ChaosConfig { lease_ticks: 256, workers_per_stage: 2, ..base_cfg(seed) };
        let reference = run_chaos(&cfg).unwrap();
        let rb = run_baseline(&cfg).unwrap();
        assert_eq!(
            reference.retired, rb.retired,
            "seed={seed}: K=1 dock must already match the sync baseline"
        );
        for k in [2usize, 4, 7] {
            for steal in [0usize, 2] {
                let scfg = with_shards(&cfg, k, steal);
                let out = run_chaos(&scfg).unwrap();
                assert_oracle(&format!("K={k} steal={steal} seed={seed}"), &scfg, &out, &reference);
                assert_eq!(
                    out.recovery.reclaimed, 0,
                    "K={k} steal={steal} seed={seed}: fault-free sharded run must not reclaim"
                );
            }
        }
    }
}

// ------------------------------------------------------ chaos composed

/// Worker kills on a sharded dock: stolen and home claims alike expire
/// at the victim shard's lease table and redispatch — converging to the
/// K=1 retired map with zero loss.
#[test]
fn sharded_dock_recovers_kills_to_the_k1_retired_map() {
    let cfg = ChaosConfig {
        iterations: 5,
        lease_ticks: 4,
        plan: FaultPlan { seed: 9, kill_rate: 0.4, ..Default::default() },
        ..base_cfg(42)
    };
    let reference =
        run_chaos(&ChaosConfig { iterations: 5, lease_ticks: 256, ..base_cfg(42) }).unwrap();
    for (k, steal) in [(2usize, 0usize), (4, 1)] {
        let scfg = with_shards(&cfg, k, steal);
        let out = run_chaos(&scfg).unwrap();
        assert_oracle(&format!("kills K={k} steal={steal}"), &scfg, &out, &reference);
        assert!(out.recovery.kills > 0, "plan must fire: {:?}", out.recovery);
        assert!(out.recovery.reclaimed > 0, "kills must surface as reclaims");
    }
}

/// Stalls with two replicas per stage: a stalled worker's claims (some
/// stolen cross-shard) are reclaimed and re-processed by its twin, the
/// zombie's late writebacks drop as superseded — same retired map.
#[test]
fn sharded_dock_with_stalls_and_replicas_drops_late_writebacks() {
    let cfg = ChaosConfig {
        iterations: 5,
        workers_per_stage: 2,
        lease_ticks: 3,
        plan: FaultPlan { seed: 21, stall_rate: 0.4, stall_ticks: 10, ..Default::default() },
        ..base_cfg(11)
    };
    let reference = run_chaos(&ChaosConfig {
        iterations: 5,
        workers_per_stage: 2,
        lease_ticks: 256,
        ..base_cfg(11)
    })
    .unwrap();
    let scfg = with_shards(&cfg, 4, 0);
    let out = run_chaos(&scfg).unwrap();
    assert_oracle("stalls K=4", &scfg, &out, &reference);
    assert!(out.recovery.stalls > 0, "plan must fire: {:?}", out.recovery);
    assert!(out.recovery.reclaimed > 0, "{:?}", out.recovery);
}

/// Streaming generation + partial rollouts + kills on a sharded dock:
/// killed sequences persist their prefixes, redispatch resumes them
/// (possibly claimed through a *different* shard than the original),
/// and the retired map — stamps included — still matches K=1.
#[test]
fn sharded_streaming_partial_rollouts_survive_kills() {
    let cfg = ChaosConfig {
        lease_ticks: 4,
        gen_streaming: true,
        partial_rollouts: true,
        plan: FaultPlan { seed: 0xc4a0_5, kill_rate: 0.3, ..Default::default() },
        ..base_cfg(3)
    };
    let reference = run_chaos(&ChaosConfig {
        lease_ticks: 256,
        gen_streaming: true,
        partial_rollouts: true,
        ..base_cfg(3)
    })
    .unwrap();
    for k in [2usize, 4] {
        let scfg = with_shards(&cfg, k, 1);
        let out = run_chaos(&scfg).unwrap();
        assert_oracle(&format!("streaming+partial K={k}"), &scfg, &out, &reference);
    }
}

/// Backlog-driven autoscaling over a sharded dock: replica counts
/// breathe, per-shard puller registration follows, and the retired map
/// is unchanged.
#[test]
fn sharded_dock_composes_with_autoscale() {
    let auto = AutoscaleConfig {
        min_replicas: 1,
        max_replicas: 3,
        backlog_hi: 2,
        backlog_lo: 0,
        up_ticks: 1,
        down_ticks: 2,
    };
    let cfg = ChaosConfig {
        lease_ticks: 256,
        autoscale: Some(auto),
        ..base_cfg(7)
    };
    let reference = run_chaos(&cfg).unwrap();
    for (k, steal) in [(2usize, 0usize), (4, 2)] {
        let scfg = with_shards(&cfg, k, steal);
        let out = run_chaos(&scfg).unwrap();
        assert_oracle(&format!("autoscale K={k} steal={steal}"), &scfg, &out, &reference);
    }
}

// -------------------------------------------------- randomized matrix

/// The fuzz hook the scheduled CI job leans on: mixed kills + stalls
/// across the seed list (fixed, plus time-derived under
/// `CHAOS_RANDOM_SEEDS=1`) on a K=4 stealing dock — every schedule must
/// satisfy the oracle against its own K=1 twin.
#[test]
fn mixed_fault_sweep_holds_the_oracle_across_seeds() {
    for seed in seeds() {
        let cfg = ChaosConfig {
            workers_per_stage: 2,
            plan: FaultPlan {
                seed: seed ^ 0xdead_beef,
                kill_rate: 0.2,
                stall_rate: 0.2,
                stall_ticks: 8,
                ..Default::default()
            },
            ..base_cfg(seed)
        };
        let reference = run_chaos(&ChaosConfig {
            workers_per_stage: 2,
            lease_ticks: 256,
            ..base_cfg(seed)
        })
        .unwrap();
        let scfg = with_shards(&cfg, 4, 1);
        let out = run_chaos(&scfg).unwrap();
        assert_oracle(&format!("mixed seed={seed}"), &scfg, &out, &reference);
    }
}
