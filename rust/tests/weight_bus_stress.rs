//! Multithreaded stress suite for the versioned `WeightBus` ring:
//! concurrent publishers and readers, eviction races, and the regression
//! contract that a reader asking for an evicted version gets a *typed
//! error*, never a panic. Runs without artifacts (host tensors only) —
//! the CI stress job executes it under `--test-threads=8` for real
//! parallelism.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use mindspeed_rl::runtime::Tensor;
use mindspeed_rl::weights::{WeightBus, WeightBusError, WeightVersion};

/// A snapshot whose payload encodes its version, so readers can verify
/// they were handed the weights they asked for.
fn params_for(version: u64) -> Vec<Tensor> {
    vec![Tensor::f32(&[2], vec![version as f32, (version * 2) as f32]).unwrap()]
}

fn tag_of(params: &[Tensor]) -> u64 {
    params[0].as_f32().unwrap()[0] as u64
}

#[test]
fn concurrent_publishers_and_readers_stay_coherent() {
    const PUBLISHERS: usize = 3;
    const READERS: usize = 4;
    const PER_PUBLISHER: usize = 200;
    const CAPACITY: usize = 8;

    let bus = Arc::new(WeightBus::new(params_for(1), CAPACITY));
    let done = Arc::new(AtomicBool::new(false));
    let good_reads = Arc::new(AtomicU64::new(0));

    std::thread::scope(|scope| {
        for _ in 0..PUBLISHERS {
            let bus = Arc::clone(&bus);
            scope.spawn(move || {
                for _ in 0..PER_PUBLISHER {
                    // a publisher cannot know its version before the call,
                    // so assert what it can: the minted version is never
                    // ahead of the head other threads observe
                    let v = bus.publish(&params_for(0)).as_u64();
                    assert!(bus.head_version().as_u64() >= v);
                }
            });
        }
        for _ in 0..READERS {
            let bus = Arc::clone(&bus);
            let done = Arc::clone(&done);
            let good_reads = Arc::clone(&good_reads);
            scope.spawn(move || {
                let mut last_seen = 0u64;
                while !done.load(Ordering::Relaxed) {
                    // head() is always servable and monotone
                    let (v, _p) = bus.head();
                    assert!(v.as_u64() >= last_seen, "head went backwards");
                    last_seen = v.as_u64();
                    // a racing get() of the observed head either succeeds
                    // or reports a *typed* eviction — never panics
                    match bus.get(v) {
                        Ok(_) => {
                            good_reads.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(WeightBusError::Evicted { requested, oldest, .. }) => {
                            assert!(requested < oldest, "eviction error fields inconsistent");
                        }
                        Err(e) => panic!("unexpected error for published head: {e}"),
                    }
                    // the ring never over-retains
                    assert!(bus.len() <= CAPACITY);
                }
            });
        }
        // publishers run to completion, then release the readers
        while bus.head_version().as_u64() < (PUBLISHERS * PER_PUBLISHER) as u64 + 1 {
            std::thread::yield_now();
        }
        done.store(true, Ordering::Relaxed);
    });

    assert_eq!(
        bus.head_version().as_u64(),
        (PUBLISHERS * PER_PUBLISHER) as u64 + 1,
        "every publish must mint exactly one version"
    );
    assert!(good_reads.load(Ordering::Relaxed) > 0, "readers never got a snapshot");
}

#[test]
fn unique_versions_under_publisher_contention() {
    const PUBLISHERS: usize = 4;
    const PER_PUBLISHER: usize = 100;
    let bus = Arc::new(WeightBus::new(params_for(1), 4));
    let minted: Vec<u64> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..PUBLISHERS)
            .map(|_| {
                let bus = Arc::clone(&bus);
                scope.spawn(move || {
                    (0..PER_PUBLISHER)
                        .map(|_| bus.publish(&params_for(0)).as_u64())
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let mut sorted = minted.clone();
    sorted.sort_unstable();
    sorted.dedup();
    assert_eq!(sorted.len(), minted.len(), "publish handed out a duplicate version");
    assert_eq!(sorted.len(), PUBLISHERS * PER_PUBLISHER);
}

/// Readers hammer the *oldest* retained version while a publisher evicts
/// from under them: every read must resolve to either the correct
/// snapshot or a well-formed typed eviction error.
#[test]
fn eviction_race_yields_snapshot_or_typed_error() {
    const CAPACITY: usize = 3;
    const PUBLISHES: u64 = 500;
    let bus = Arc::new(WeightBus::new(params_for(1), CAPACITY));
    let done = Arc::new(AtomicBool::new(false));

    std::thread::scope(|scope| {
        for _ in 0..4 {
            let bus = Arc::clone(&bus);
            let done = Arc::clone(&done);
            scope.spawn(move || {
                while !done.load(Ordering::Relaxed) {
                    let oldest = bus.oldest();
                    match bus.get(oldest) {
                        // correctness: the snapshot handed back is the one
                        // the version names (payload encodes the version)
                        Ok(p) => assert_eq!(tag_of(&p), oldest.as_u64(), "wrong snapshot served"),
                        Err(WeightBusError::Evicted { requested, oldest: o, newest }) => {
                            assert_eq!(requested, oldest.as_u64());
                            assert!(o > requested && newest >= o, "error fields inconsistent");
                        }
                        Err(e) => panic!("oldest() race must only evict, got {e}"),
                    }
                }
            });
        }
        {
            let bus = Arc::clone(&bus);
            let done = Arc::clone(&done);
            scope.spawn(move || {
                for _ in 0..PUBLISHES {
                    let v = bus.head_version().as_u64() + 1;
                    bus.publish(&params_for(v));
                    std::thread::yield_now();
                }
                done.store(true, Ordering::Relaxed);
            });
        }
    });
    assert_eq!(bus.head_version().as_u64(), PUBLISHES + 1);
    assert_eq!(bus.oldest().as_u64(), PUBLISHES + 1 - (CAPACITY as u64 - 1));
}

/// The regression case from the issue: a reader requesting an evicted
/// version gets a typed error — and the staleness window (ring capacity)
/// is exactly what separates servable from evicted.
#[test]
fn evicted_version_is_a_typed_error_not_a_panic() {
    let window = 4usize;
    let bus = WeightBus::new(params_for(1), window);
    for v in 2..=10u64 {
        bus.publish(&params_for(v));
    }
    // head 10, ring holds 7..=10 (window = 4)
    assert_eq!(bus.head_version(), WeightVersion(10));
    assert_eq!(bus.oldest(), WeightVersion(7));
    assert_eq!(bus.len(), window);
    // everything inside the window serves the exact stamped snapshot
    for v in 7..=10u64 {
        assert_eq!(tag_of(&bus.get(WeightVersion(v)).unwrap()), v);
    }
    // everything outside is a typed, field-complete error
    for v in 1..7u64 {
        match bus.get(WeightVersion(v)) {
            Err(WeightBusError::Evicted { requested, oldest, newest }) => {
                assert_eq!((requested, oldest, newest), (v, 7, 10));
            }
            other => panic!("v{v}: expected Evicted, got {other:?}"),
        }
    }
    match bus.get(WeightVersion(11)) {
        Err(WeightBusError::NotYetPublished { requested: 11, newest: 10 }) => {}
        other => panic!("expected NotYetPublished, got {other:?}"),
    }
    // the error formats without panicking (used in stage failure paths)
    let msg = bus.get(WeightVersion(1)).unwrap_err().to_string();
    assert!(msg.contains("v1") && msg.contains("evicted"), "{msg}");
}

/// A reader holding an `Arc` to a snapshot keeps it usable after the
/// ring evicts it — eviction only drops the bus's own reference.
#[test]
fn held_snapshots_outlive_eviction() {
    let bus = WeightBus::new(params_for(1), 2);
    let held = bus.get(WeightVersion(1)).unwrap();
    for v in 2..=6u64 {
        bus.publish(&params_for(v));
    }
    assert!(matches!(bus.get(WeightVersion(1)), Err(WeightBusError::Evicted { .. })));
    assert_eq!(tag_of(&held), 1, "held snapshot corrupted by eviction");
}
