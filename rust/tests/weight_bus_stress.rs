//! Multithreaded stress + property suite for the versioned `WeightBus`
//! ring with shard-level, content-deduplicated retention: concurrent
//! publishers and readers, eviction races, the regression contract that a
//! reader asking for an evicted version gets a *typed error* (never a
//! panic), and the retention properties — every retained version
//! reconstructs bit-identically to a from-scratch full snapshot, and
//! pool-charged bus bytes equal Σ live unique shard bytes at every point
//! of a randomized publish/evict sequence. Runs without artifacts (host
//! tensors only) — the CI stress job executes it under
//! `--test-threads=8` for real parallelism.

use std::collections::{HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use mindspeed_rl::memory::MemoryPool;
use mindspeed_rl::runtime::Tensor;
use mindspeed_rl::util::rng::Rng;
use mindspeed_rl::weights::{WeightBus, WeightBusError, WeightVersion, WeightView};

/// A snapshot whose payload encodes its version, so readers can verify
/// they were handed the weights they asked for.
fn params_for(version: u64) -> Vec<Tensor> {
    vec![Tensor::f32(&[2], vec![version as f32, (version * 2) as f32]).unwrap()]
}

fn tag_of(view: &WeightView) -> u64 {
    view.tensor(0).as_f32().unwrap()[0] as u64
}

#[test]
fn concurrent_publishers_and_readers_stay_coherent() {
    const PUBLISHERS: usize = 3;
    const READERS: usize = 4;
    const PER_PUBLISHER: usize = 200;
    const CAPACITY: usize = 8;

    let bus = Arc::new(WeightBus::new(params_for(1), CAPACITY));
    let done = Arc::new(AtomicBool::new(false));
    let good_reads = Arc::new(AtomicU64::new(0));

    std::thread::scope(|scope| {
        for _ in 0..PUBLISHERS {
            let bus = Arc::clone(&bus);
            scope.spawn(move || {
                for _ in 0..PER_PUBLISHER {
                    // a publisher cannot know its version before the call,
                    // so assert what it can: the minted version is never
                    // ahead of the head other threads observe
                    let v = bus.publish(&params_for(0)).unwrap().as_u64();
                    assert!(bus.head_version().as_u64() >= v);
                }
            });
        }
        for _ in 0..READERS {
            let bus = Arc::clone(&bus);
            let done = Arc::clone(&done);
            let good_reads = Arc::clone(&good_reads);
            scope.spawn(move || {
                let mut last_seen = 0u64;
                while !done.load(Ordering::Relaxed) {
                    // head() is always servable and monotone
                    let (v, _view) = bus.head();
                    assert!(v.as_u64() >= last_seen, "head went backwards");
                    last_seen = v.as_u64();
                    // a racing get() of the observed head either succeeds
                    // or reports a *typed* eviction — never panics
                    match bus.get(v) {
                        Ok(_) => {
                            good_reads.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(WeightBusError::Evicted { requested, oldest, .. }) => {
                            assert!(requested < oldest, "eviction error fields inconsistent");
                        }
                        Err(e) => panic!("unexpected error for published head: {e}"),
                    }
                    // the ring never over-retains
                    assert!(bus.len() <= CAPACITY);
                }
            });
        }
        // publishers run to completion, then release the readers
        while bus.head_version().as_u64() < (PUBLISHERS * PER_PUBLISHER) as u64 + 1 {
            std::thread::yield_now();
        }
        done.store(true, Ordering::Relaxed);
    });

    assert_eq!(
        bus.head_version().as_u64(),
        (PUBLISHERS * PER_PUBLISHER) as u64 + 1,
        "every publish must mint exactly one version"
    );
    assert!(good_reads.load(Ordering::Relaxed) > 0, "readers never got a snapshot");
}

#[test]
fn unique_versions_under_publisher_contention() {
    const PUBLISHERS: usize = 4;
    const PER_PUBLISHER: usize = 100;
    let bus = Arc::new(WeightBus::new(params_for(1), 4));
    let minted: Vec<u64> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..PUBLISHERS)
            .map(|_| {
                let bus = Arc::clone(&bus);
                scope.spawn(move || {
                    (0..PER_PUBLISHER)
                        .map(|_| bus.publish(&params_for(0)).unwrap().as_u64())
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let mut sorted = minted.clone();
    sorted.sort_unstable();
    sorted.dedup();
    assert_eq!(sorted.len(), minted.len(), "publish handed out a duplicate version");
    assert_eq!(sorted.len(), PUBLISHERS * PER_PUBLISHER);
}

/// Readers hammer the *oldest* retained version while a publisher evicts
/// from under them: every read must resolve to either the correct
/// snapshot or a well-formed typed eviction error — and the accounting
/// pool's charges must balance exactly once the dust settles.
#[test]
fn eviction_race_yields_snapshot_or_typed_error() {
    const CAPACITY: usize = 3;
    const PUBLISHES: u64 = 500;
    let pool = Arc::new(MemoryPool::unbounded("weightbus"));
    let bus =
        Arc::new(WeightBus::new_with_pool(params_for(1), CAPACITY, Arc::clone(&pool)).unwrap());
    let done = Arc::new(AtomicBool::new(false));

    std::thread::scope(|scope| {
        for _ in 0..4 {
            let bus = Arc::clone(&bus);
            let done = Arc::clone(&done);
            scope.spawn(move || {
                while !done.load(Ordering::Relaxed) {
                    let oldest = bus.oldest();
                    match bus.get(oldest) {
                        // correctness: the snapshot handed back is the one
                        // the version names (payload encodes the version)
                        Ok(view) => {
                            assert_eq!(tag_of(&view), oldest.as_u64(), "wrong snapshot served")
                        }
                        Err(WeightBusError::Evicted { requested, oldest: o, newest }) => {
                            assert_eq!(requested, oldest.as_u64());
                            assert!(o > requested && newest >= o, "error fields inconsistent");
                        }
                        Err(e) => panic!("oldest() race must only evict, got {e}"),
                    }
                }
            });
        }
        {
            let bus = Arc::clone(&bus);
            let done = Arc::clone(&done);
            scope.spawn(move || {
                for _ in 0..PUBLISHES {
                    let v = bus.head_version().as_u64() + 1;
                    bus.publish(&params_for(v)).unwrap();
                    std::thread::yield_now();
                }
                done.store(true, Ordering::Relaxed);
            });
        }
    });
    assert_eq!(bus.head_version().as_u64(), PUBLISHES + 1);
    assert_eq!(bus.oldest().as_u64(), PUBLISHES + 1 - (CAPACITY as u64 - 1));
    // reader-held views do not keep pool charges alive: after the race,
    // charges equal exactly the unique bytes the ring retains
    assert_eq!(pool.live_bytes(), bus.retained_bytes());
    assert!(pool.live_bytes() > 0);
}

/// The regression case from the issue: a reader requesting an evicted
/// version gets a typed error — and the staleness window (ring capacity)
/// is exactly what separates servable from evicted.
#[test]
fn evicted_version_is_a_typed_error_not_a_panic() {
    let window = 4usize;
    let bus = WeightBus::new(params_for(1), window);
    for v in 2..=10u64 {
        bus.publish(&params_for(v)).unwrap();
    }
    // head 10, ring holds 7..=10 (window = 4)
    assert_eq!(bus.head_version(), WeightVersion(10));
    assert_eq!(bus.oldest(), WeightVersion(7));
    assert_eq!(bus.len(), window);
    // everything inside the window serves the exact stamped snapshot
    for v in 7..=10u64 {
        assert_eq!(tag_of(&bus.get(WeightVersion(v)).unwrap()), v);
    }
    // everything outside is a typed, field-complete error
    for v in 1..7u64 {
        match bus.get(WeightVersion(v)) {
            Err(WeightBusError::Evicted { requested, oldest, newest }) => {
                assert_eq!((requested, oldest, newest), (v, 7, 10));
            }
            other => panic!("v{v}: expected Evicted, got {other:?}"),
        }
    }
    match bus.get(WeightVersion(11)) {
        Err(WeightBusError::NotYetPublished { requested: 11, newest: 10 }) => {}
        other => panic!("expected NotYetPublished, got {other:?}"),
    }
    // the error formats without panicking (used in stage failure paths)
    let msg = bus.get(WeightVersion(1)).unwrap_err().to_string();
    assert!(msg.contains("v1") && msg.contains("evicted"), "{msg}");
}

/// A reader holding a view keeps its shards usable after the ring evicts
/// the version — eviction only drops the bus's own references.
#[test]
fn held_snapshots_outlive_eviction() {
    let bus = WeightBus::new(params_for(1), 2);
    let held = bus.get(WeightVersion(1)).unwrap();
    for v in 2..=6u64 {
        bus.publish(&params_for(v)).unwrap();
    }
    assert!(matches!(bus.get(WeightVersion(1)), Err(WeightBusError::Evicted { .. })));
    assert_eq!(tag_of(&held), 1, "held snapshot corrupted by eviction");
}

/// An undersized ring is a typed error at build time — the regression
/// was test code passing `capacity=1` with a staleness window of 2 and
/// dying mid-run with `Evicted` deep inside the old-logprob stage.
#[test]
fn undersized_ring_rejected_at_build_time() {
    match WeightBus::new_checked(params_for(1), 1, 2, 16, None) {
        Err(WeightBusError::CapacityBelowWindow { capacity: 1, required, window: 2 }) => {
            assert_eq!(required, WeightBus::required_capacity(2, 16));
        }
        other => panic!("expected CapacityBelowWindow, got {:?}", other.map(|_| ())),
    }
    assert!(WeightBus::new_checked(
        params_for(1),
        WeightBus::required_capacity(2, 16),
        2,
        16,
        None
    )
    .is_ok());
}

/// Multi-tensor model for the retention properties: each tensor's
/// payload encodes (tensor index, mutation counter), so reconstruction
/// errors are attributable.
fn model(vals: &[f32]) -> Vec<Tensor> {
    vals.iter()
        .enumerate()
        .map(|(i, &v)| Tensor::f32(&[8], vec![v + i as f32 * 1000.0; 8]).unwrap())
        .collect()
}

/// Property: after every step of a randomized publish/evict sequence in
/// which each publish mutates a random subset of tensors,
///
/// (1) every retained version reconstructs **bit-identically** to the
///     from-scratch full snapshot recorded when it was published,
/// (2) `retained_bytes` equals Σ bytes over the unique (tensor, epoch)
///     shards a faithful shadow of the dedup scheme predicts, and
/// (3) the accounting pool's live bytes equal `retained_bytes` exactly.
#[test]
fn shard_retention_bit_identical_and_pool_accounted_under_random_publishes() {
    const N_TENSORS: usize = 6;
    const CAPACITY: usize = 5;
    const STEPS: usize = 150;
    let tensor_bytes = 8u64 * 4;

    let mut rng = Rng::new(0x5eed_cafe);
    let mut vals = vec![0f32; N_TENSORS];
    let pool = Arc::new(MemoryPool::unbounded("weightbus"));
    let bus = WeightBus::new_with_pool(model(&vals), CAPACITY, Arc::clone(&pool)).unwrap();

    // shadow: (version, full snapshot, per-tensor content epochs)
    let mut epochs = vec![1u64; N_TENSORS];
    let mut shadow: VecDeque<(u64, Vec<Tensor>, Vec<u64>)> = VecDeque::new();
    shadow.push_back((1, model(&vals), epochs.clone()));

    for step in 0..STEPS {
        let version = step as u64 + 2;
        // mutate a random subset (sometimes empty — a no-op publish)
        for (i, v) in vals.iter_mut().enumerate() {
            if rng.below(3) == 0 {
                *v += 1.0;
                epochs[i] = version;
            }
        }
        assert_eq!(bus.publish(&model(&vals)).unwrap().as_u64(), version);
        shadow.push_back((version, model(&vals), epochs.clone()));
        while shadow.len() > CAPACITY {
            shadow.pop_front();
        }

        // (1) bit-identical reconstruction of every retained version
        for (sv, snap, _) in &shadow {
            let view = bus.get(WeightVersion(*sv)).unwrap();
            assert_eq!(
                &view.to_params(),
                snap,
                "step {step}: v{sv} reconstruction differs from its full snapshot"
            );
        }
        // just-evicted versions are typed errors
        let oldest = shadow.front().unwrap().0;
        if oldest > 1 {
            assert!(matches!(
                bus.get(WeightVersion(oldest - 1)),
                Err(WeightBusError::Evicted { .. })
            ));
        }

        // (2) retained bytes == Σ unique (tensor, epoch) shard bytes
        let mut unique: HashSet<(usize, u64)> = HashSet::new();
        for (_, _, eps) in &shadow {
            for (i, e) in eps.iter().enumerate() {
                unique.insert((i, *e));
            }
        }
        assert_eq!(bus.retained_shards(), unique.len(), "step {step}");
        assert_eq!(bus.retained_bytes(), unique.len() as u64 * tensor_bytes, "step {step}");

        // (3) pool charges mirror retention exactly, publish after evict
        assert_eq!(pool.live_bytes(), bus.retained_bytes(), "step {step}");
    }
    assert!(pool.peak_bytes() >= pool.live_bytes());
    assert_eq!(bus.peak_retained_bytes(), pool.peak_bytes());
}

/// The acceptance-criterion accounting assertion: when only a subset of
/// tensors changes per publish, shard-level retention stores **strictly
/// fewer** bytes than `len() × full-model bytes` (what PR 2's full-copy
/// ring held).
#[test]
fn subset_changes_store_strictly_fewer_bytes_than_full_copies() {
    const N_TENSORS: usize = 4;
    const CAPACITY: usize = 8;
    let tensor_bytes = 8u64 * 4;
    let full_bytes = N_TENSORS as u64 * tensor_bytes;

    let mut vals = vec![0f32; N_TENSORS];
    let bus = WeightBus::new(model(&vals), CAPACITY);
    // each publish changes tensor 0 only
    for _ in 0..(CAPACITY - 1) {
        vals[0] += 1.0;
        bus.publish(&model(&vals)).unwrap();
    }
    assert_eq!(bus.len(), CAPACITY);
    assert_eq!(
        bus.naive_equivalent_bytes(),
        bus.len() as u64 * full_bytes,
        "the full-copy equivalent is len() × full-model bytes"
    );
    assert!(
        bus.retained_bytes() < bus.len() as u64 * full_bytes,
        "shard retention ({}) must be strictly below the full-copy ring ({})",
        bus.retained_bytes(),
        bus.len() as u64 * full_bytes
    );
    // exactly: one full model + one changed shard per later version
    assert_eq!(
        bus.retained_bytes(),
        full_bytes + (CAPACITY as u64 - 1) * tensor_bytes
    );
}
